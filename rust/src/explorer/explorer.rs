//! The Explorer actor (paper Fig. 3): takes task batches, executes
//! workflows through the runner, streams experiences into the buffer,
//! participates in weight sync, and serves bench-mode evaluation.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::buffer::ExperienceBuffer;
use crate::envs::math::verify;
use crate::exec::ThreadPool;
use crate::model::{WeightSnapshot, WeightSync};
use crate::tokenizer::Tokenizer;
use crate::util::json::Value;

use super::generation::{GenerationEngine, RolloutEndpoint, RolloutModel, SamplingArgs};
use super::runner::{RunnerConfig, RunnerEvent, RunnerStats, WorkflowRunner};
use super::workflow::{Task, WorkflowRegistry};

#[derive(Clone)]
pub struct ExplorerConfig {
    pub runner: RunnerConfig,
    pub sampling: SamplingArgs,
    /// Worker threads for workflow execution.
    pub threads: usize,
}

impl Default for ExplorerConfig {
    fn default() -> Self {
        ExplorerConfig {
            runner: RunnerConfig::default(),
            sampling: SamplingArgs::default(),
            threads: 2,
        }
    }
}

pub struct Explorer {
    pub id: usize,
    /// The model tier this explorer rolls out against: either a direct
    /// [`GenerationEngine`] handle (seed wiring) or a shared
    /// `service::RolloutService` handle (the paper's model service).
    endpoint: Arc<dyn RolloutEndpoint>,
    /// Same object as `endpoint`, pre-coerced for the runner's
    /// `Arc<dyn RolloutModel>` parameter.
    model: Arc<dyn RolloutModel>,
    /// Set only when the endpoint IS a direct engine handle.
    engine: Option<Arc<GenerationEngine>>,
    runner: WorkflowRunner,
    registry: Arc<WorkflowRegistry>,
    tokenizer: Arc<Tokenizer>,
    buffer: Arc<dyn ExperienceBuffer>,
    config: ExplorerConfig,
    batches_done: AtomicU64,
    pool: Arc<ThreadPool>,
}

#[derive(Debug, Clone, Default)]
pub struct EvalReport {
    /// Mean reward over all rollouts (Avg@K).
    pub avg_reward: f64,
    /// Fraction of tasks with at least one correct rollout (Pass@K).
    pub pass_at_k: f64,
    pub mean_response_len: f64,
    pub tasks: usize,
    pub rollouts: usize,
}

impl Explorer {
    pub fn new(
        id: usize,
        engine: Arc<GenerationEngine>,
        registry: Arc<WorkflowRegistry>,
        tokenizer: Arc<Tokenizer>,
        buffer: Arc<dyn ExperienceBuffer>,
        config: ExplorerConfig,
    ) -> Explorer {
        let mut explorer = Self::with_endpoint(id, Arc::clone(&engine), registry, tokenizer, buffer, config);
        explorer.engine = Some(engine);
        explorer
    }

    /// An explorer over any [`RolloutEndpoint`] — notably a shared
    /// rollout-service handle, so N explorers can serve rollouts from
    /// one replica pool.
    pub fn with_endpoint<M: RolloutEndpoint + 'static>(
        id: usize,
        endpoint: Arc<M>,
        registry: Arc<WorkflowRegistry>,
        tokenizer: Arc<Tokenizer>,
        buffer: Arc<dyn ExperienceBuffer>,
        config: ExplorerConfig,
    ) -> Explorer {
        let pool = Arc::new(ThreadPool::new(&format!("explorer-{id}"), config.threads));
        let runner = WorkflowRunner::new(Arc::clone(&pool), config.runner.clone());
        let model: Arc<dyn RolloutModel> = Arc::clone(&endpoint) as Arc<dyn RolloutModel>;
        Explorer {
            id,
            endpoint,
            model,
            engine: None,
            runner,
            registry,
            tokenizer,
            buffer,
            config,
            batches_done: AtomicU64::new(0),
            pool,
        }
    }

    /// The direct engine handle (panics for service-backed explorers —
    /// use [`endpoint`](Self::endpoint) there).
    pub fn engine(&self) -> &Arc<GenerationEngine> {
        self.engine
            .as_ref()
            .expect("explorer is service-backed; use Explorer::endpoint() instead of engine()")
    }

    pub fn endpoint(&self) -> &Arc<dyn RolloutEndpoint> {
        &self.endpoint
    }

    pub fn pool(&self) -> &Arc<ThreadPool> {
        &self.pool
    }

    pub fn weight_version(&self) -> u64 {
        self.endpoint.weight_version()
    }

    /// Explore one batch of tasks, streaming experiences into the buffer
    /// as tasks complete.
    pub fn explore_batch(&self, tasks: Vec<Task>) -> Result<RunnerStats> {
        let rx = self.runner.run_streaming(
            tasks,
            Arc::clone(&self.registry),
            Arc::clone(&self.model),
            Arc::clone(&self.tokenizer),
            self.config.sampling.clone(),
        );
        let mut stats = RunnerStats::default();
        while let Ok(ev) = rx.recv() {
            match ev {
                RunnerEvent::Done { experiences, .. } => {
                    stats.completed += 1;
                    stats.experiences += experiences.len();
                    if !experiences.is_empty() {
                        self.buffer.write(experiences)?;
                    }
                }
                RunnerEvent::Skipped { task_id, error } => {
                    stats.skipped += 1;
                    if error == "timeout" {
                        stats.timeouts += 1;
                    }
                    crate::log_warn!("explorer", "task {task_id} skipped: {error}");
                }
            }
        }
        self.batches_done.fetch_add(1, Ordering::SeqCst);
        Ok(stats)
    }

    pub fn batches_done(&self) -> u64 {
        self.batches_done.load(Ordering::SeqCst)
    }

    /// Ready depth of the shared experience buffer — feeds the
    /// scheduler's `Progress` so buffer-pressure-aware sync policies can
    /// throttle admission instead of relying on blocking writes.
    pub fn buffer_depth(&self) -> usize {
        self.buffer.ready_len()
    }

    /// Pull newer weights if published (returns true when updated).  A
    /// service-backed explorer rolls the pull across the replica pool.
    pub fn sync_weights(&self, sync: &dyn WeightSync) -> Result<bool> {
        self.endpoint.sync_weights(sync)
    }

    /// Overwrite the endpoint's weights from a shared snapshot (initial
    /// load / bench over checkpoints).
    pub fn set_weights(&self, snapshot: &WeightSnapshot, version: u64) -> Result<()> {
        self.endpoint.set_weights(snapshot, version)
    }

    /// Bench mode (paper §2.1.1): evaluate the current weights on a task
    /// set without writing to the buffer.  Avg@K over `repeat_times`
    /// rollouts per task, greedy-ish low temperature.
    pub fn evaluate(&self, tasks: &[Task], temperature: f32) -> Result<EvalReport> {
        let mut report = EvalReport { tasks: tasks.len(), ..Default::default() };
        // eval traffic runs under its own QoS class: with `[qos]` on it
        // gets its DRR share (and per-class deadline/cap) instead of
        // competing head-to-head with bulk training rollouts
        let sampling = SamplingArgs {
            temperature,
            class: crate::qos::RequestClass::Eval,
            ..self.config.sampling.clone()
        };
        let mut total_reward = 0.0;
        let mut total_len = 0.0;
        let mut rollouts = 0usize;
        for task in tasks {
            let question = task.payload.get("question").and_then(Value::as_str).unwrap_or("");
            let answer: i64 = task
                .payload
                .get("answer")
                .and_then(Value::as_str)
                .and_then(|s| s.parse().ok())
                .unwrap_or(0);
            let prompt = self.tokenizer.encode_prompt(question);
            let outs = self.model.chat(&prompt, task.repeat_times.max(1), &sampling)?;
            let mut any_correct = false;
            for out in &outs {
                let resp = self.tokenizer.decode_response(&out.tokens, out.prompt_len);
                let r = verify(&resp, answer);
                if r > 0.5 {
                    any_correct = true;
                }
                total_reward += r as f64;
                total_len += (out.tokens.len() - out.prompt_len) as f64;
                rollouts += 1;
            }
            if any_correct {
                report.pass_at_k += 1.0;
            }
        }
        report.rollouts = rollouts;
        if rollouts > 0 {
            report.avg_reward = total_reward / rollouts as f64;
            report.mean_response_len = total_len / rollouts as f64;
        }
        if !tasks.is_empty() {
            report.pass_at_k /= tasks.len() as f64;
        }
        Ok(report)
    }

    /// Utilization of this explorer's worker pool (the per-"device" metric
    /// for Tables 1–2).
    pub fn utilization_percent(&self) -> f64 {
        self.pool.utilization_percent()
    }

    pub fn reset_utilization(&self) {
        self.pool.reset_utilization();
    }

    /// Wait until the buffer has drained below a watermark (backpressure
    /// for async modes).
    pub fn wait_for_buffer_below(&self, watermark: usize, timeout: Duration) -> bool {
        let deadline = std::time::Instant::now() + timeout;
        while self.buffer.ready_len() > watermark {
            if std::time::Instant::now() >= deadline {
                return false;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        true
    }
}
