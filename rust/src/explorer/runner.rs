//! Workflow runners: execute workflows on the explorer's thread pool with
//! the paper's §2.2 fault tolerance — per-task timeout, bounded retry,
//! skip-on-failure — and *streaming* completion so stragglers never block
//! already-finished experiences from reaching the buffer.
//!
//! Runners are model-agnostic clients: the `Arc<dyn RolloutModel>` they
//! take is either a direct engine handle or a `service::ServiceHandle`,
//! in which case concurrent runners' requests coalesce into shared
//! engine batches behind the rollout service's microbatcher.

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::buffer::Experience;
use crate::exec::{bounded, Receiver, TaskError, ThreadPool};
use crate::tokenizer::Tokenizer;
use crate::util::rng::Rng;

use super::generation::{RolloutModel, SamplingArgs};
use super::workflow::{Task, WorkflowCtx, WorkflowRegistry};

#[derive(Debug, Clone)]
pub struct RunnerConfig {
    /// Per-task wall-clock timeout.
    pub timeout: Duration,
    /// Attempts per task (1 = no retry).
    pub max_attempts: usize,
    pub retry_delay: Duration,
    /// Seed for per-task RNG streams.
    pub seed: u64,
}

impl Default for RunnerConfig {
    fn default() -> Self {
        RunnerConfig {
            timeout: Duration::from_secs(120),
            max_attempts: 2,
            retry_delay: Duration::from_millis(20),
            seed: 0,
        }
    }
}

#[derive(Debug, Default, Clone, PartialEq)]
pub struct RunnerStats {
    pub completed: usize,
    pub experiences: usize,
    pub retried: usize,
    pub skipped: usize,
    pub timeouts: usize,
}

/// Events emitted on the streaming channel as tasks finish.
pub enum RunnerEvent {
    Done { task_id: String, experiences: Vec<Experience> },
    Skipped { task_id: String, error: String },
}

pub struct WorkflowRunner {
    pool: Arc<ThreadPool>,
    pub config: RunnerConfig,
}

impl WorkflowRunner {
    pub fn new(pool: Arc<ThreadPool>, config: RunnerConfig) -> WorkflowRunner {
        WorkflowRunner { pool, config }
    }

    pub fn pool(&self) -> &Arc<ThreadPool> {
        &self.pool
    }

    /// Launch all tasks; returns a streaming receiver of per-task events.
    /// Experiences arrive as soon as each task completes (straggler
    /// mitigation), in completion order.
    pub fn run_streaming(
        &self,
        tasks: Vec<Task>,
        registry: Arc<WorkflowRegistry>,
        model: Arc<dyn RolloutModel>,
        tokenizer: Arc<Tokenizer>,
        sampling: SamplingArgs,
    ) -> Receiver<RunnerEvent> {
        let (tx, rx) = bounded::<RunnerEvent>(tasks.len().max(1));
        let config = self.config.clone();
        let mut promises = Vec::with_capacity(tasks.len());
        for (i, task) in tasks.into_iter().enumerate() {
            let registry = Arc::clone(&registry);
            let model = Arc::clone(&model);
            let tokenizer = Arc::clone(&tokenizer);
            let sampling = sampling.clone();
            let cfg = config.clone();
            let promise = self.pool.submit(move || -> (Task, Result<Vec<Experience>>, usize) {
                let mut attempts_used = 0;
                let mut last_err: Option<anyhow::Error> = None;
                for attempt in 0..cfg.max_attempts {
                    attempts_used = attempt + 1;
                    let wf = match registry.get(&task.workflow) {
                        Ok(wf) => wf,
                        Err(e) => return (task, Err(e), attempts_used),
                    };
                    let mut ctx = WorkflowCtx {
                        model: model.as_ref(),
                        tokenizer: &tokenizer,
                        task: &task,
                        sampling: SamplingArgs {
                            seed: cfg.seed
                                ^ (i as u64) << 20
                                ^ (attempt as u64) << 40
                                ^ sampling.seed,
                            // single-turn episodes get a per-task trace
                            // id (| 1 keeps it nonzero); multi-turn
                            // workflows override it with their session
                            // key inside chat_turn
                            trace: if sampling.trace == 0 {
                                task.group_id().wrapping_add(i as u64) | 1
                            } else {
                                sampling.trace
                            },
                            // a caller-set class (the eval driver) wins;
                            // otherwise the workflow declares its own
                            class: if sampling.class == Default::default() {
                                wf.class()
                            } else {
                                sampling.class
                            },
                            ..sampling.clone()
                        },
                        rng: Rng::with_stream(cfg.seed.wrapping_add(i as u64), attempt as u64 | 1),
                    };
                    match wf.run(&mut ctx) {
                        Ok(exps) => return (task, Ok(exps), attempts_used),
                        Err(e) => {
                            last_err = Some(e);
                            if attempt + 1 < cfg.max_attempts {
                                std::thread::sleep(cfg.retry_delay);
                            }
                        }
                    }
                }
                (task, Err(last_err.unwrap()), attempts_used)
            });
            promises.push(promise);
        }

        // collector thread: applies the timeout per task and forwards
        // events in completion order (polling, so one straggler can't
        // block the rest)
        let timeout = config.timeout;
        std::thread::spawn(move || {
            let deadline = std::time::Instant::now() + timeout;
            let mut pending: Vec<_> = promises.into_iter().enumerate().collect();
            let mut timed_out: Vec<usize> = vec![];
            while !pending.is_empty() {
                let mut still = Vec::with_capacity(pending.len());
                for (i, p) in pending {
                    match p.try_take() {
                        Some(Ok((task, Ok(exps), _attempts))) => {
                            let _ = tx.send(RunnerEvent::Done { task_id: task.id, experiences: exps });
                        }
                        Some(Ok((task, Err(e), _))) => {
                            let _ = tx.send(RunnerEvent::Skipped {
                                task_id: task.id,
                                error: format!("{e:#}"),
                            });
                        }
                        Some(Err(TaskError::Panicked(msg))) => {
                            let _ = tx.send(RunnerEvent::Skipped {
                                task_id: format!("task-{i}"),
                                error: format!("panic: {msg}"),
                            });
                        }
                        Some(Err(e)) => {
                            let _ = tx.send(RunnerEvent::Skipped {
                                task_id: format!("task-{i}"),
                                error: e.to_string(),
                            });
                        }
                        None => {
                            if std::time::Instant::now() >= deadline {
                                timed_out.push(i);
                            } else {
                                still.push((i, p));
                            }
                        }
                    }
                }
                for i in timed_out.drain(..) {
                    let _ = tx.send(RunnerEvent::Skipped {
                        task_id: format!("task-{i}"),
                        error: "timeout".to_string(),
                    });
                }
                pending = still;
                if !pending.is_empty() {
                    std::thread::sleep(Duration::from_millis(2));
                }
            }
            tx.close();
        });
        rx
    }

    /// Convenience: run tasks and collect everything (plus stats).
    pub fn run_collect(
        &self,
        tasks: Vec<Task>,
        registry: Arc<WorkflowRegistry>,
        model: Arc<dyn RolloutModel>,
        tokenizer: Arc<Tokenizer>,
        sampling: SamplingArgs,
    ) -> (Vec<Experience>, RunnerStats) {
        let rx = self.run_streaming(tasks, registry, model, tokenizer, sampling);
        let mut stats = RunnerStats::default();
        let mut out = Vec::new();
        while let Ok(ev) = rx.recv() {
            match ev {
                RunnerEvent::Done { experiences, .. } => {
                    stats.completed += 1;
                    stats.experiences += experiences.len();
                    out.extend(experiences);
                }
                RunnerEvent::Skipped { error, .. } => {
                    stats.skipped += 1;
                    if error == "timeout" {
                        stats.timeouts += 1;
                    }
                }
            }
        }
        (out, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::explorer::generation::MockModel;
    use crate::util::json::Value;

    fn math_tasks(n: usize) -> Vec<Task> {
        (0..n)
            .map(|i| {
                let mut t = Task::new(
                    &format!("t{i}"),
                    "math",
                    Value::obj(vec![
                        ("question", Value::str("what is 3 + 4 ?")),
                        ("answer", Value::str("7")),
                    ]),
                );
                t.repeat_times = 2;
                t
            })
            .collect()
    }

    fn setup(model: MockModel) -> (WorkflowRunner, Arc<WorkflowRegistry>, Arc<dyn RolloutModel>, Arc<Tokenizer>) {
        let pool = Arc::new(ThreadPool::new("test-explorer", 4));
        let runner = WorkflowRunner::new(
            pool,
            RunnerConfig {
                timeout: Duration::from_secs(2),
                max_attempts: 3,
                retry_delay: Duration::from_millis(1),
                seed: 7,
            },
        );
        (
            runner,
            Arc::new(WorkflowRegistry::with_builtins()),
            Arc::new(model),
            Arc::new(Tokenizer::new()),
        )
    }

    #[test]
    fn all_tasks_complete_and_stream() {
        let (runner, reg, model, tok) = setup(MockModel::new(1, Duration::from_millis(5), 0.0));
        let (exps, stats) =
            runner.run_collect(math_tasks(8), reg, model, tok, SamplingArgs::default());
        assert_eq!(stats.completed, 8);
        assert_eq!(stats.skipped, 0);
        assert_eq!(exps.len(), 16); // repeat_times = 2
    }

    #[test]
    fn transient_failures_are_retried() {
        // fail_rate 0.5 with 3 attempts: nearly all should eventually pass
        let (runner, reg, model, tok) = setup(MockModel::new(2, Duration::ZERO, 0.5));
        let (_, stats) = runner.run_collect(math_tasks(12), reg, model, tok, SamplingArgs::default());
        assert!(stats.completed >= 9, "retries should rescue most tasks: {stats:?}");
    }

    #[test]
    fn hard_failures_are_skipped_not_fatal() {
        let (runner, reg, model, tok) = setup(MockModel::new(3, Duration::ZERO, 1.0));
        let (exps, stats) = runner.run_collect(math_tasks(5), reg, model, tok, SamplingArgs::default());
        assert_eq!(stats.completed, 0);
        assert_eq!(stats.skipped, 5);
        assert!(exps.is_empty());
    }

    #[test]
    fn unknown_workflow_is_skipped() {
        let (runner, reg, model, tok) = setup(MockModel::new(4, Duration::ZERO, 0.0));
        let tasks = vec![Task::new("x", "does_not_exist", Value::Object(vec![]))];
        let (_, stats) = runner.run_collect(tasks, reg, model, tok, SamplingArgs::default());
        assert_eq!(stats.skipped, 1);
    }

    #[test]
    fn straggler_does_not_block_stream() {
        // 3 fast tasks + 1 slow; fast results must arrive before the slow one
        let tok = Tokenizer::new();
        let slow_marker = tok.encode_prompt("what is 9 + 9 ?");
        let model = MockModel::new(5, Duration::ZERO, 0.0).with_response(move |prompt, rng| {
            if prompt == slow_marker.as_slice() {
                std::thread::sleep(Duration::from_millis(300));
            }
            let mut r: Vec<i32> = vec![100 + rng.below(5) as i32];
            r.push(crate::tokenizer::EOS);
            r
        });
        let (runner, reg, model, tok) = setup(model);
        let mut tasks = math_tasks(3);
        tasks.push(Task::new(
            "slow",
            "math",
            Value::obj(vec![("question", Value::str("what is 9 + 9 ?")), ("answer", Value::str("18"))]),
        ));
        let start = std::time::Instant::now();
        let rx = runner.run_streaming(tasks, reg, model, tok, SamplingArgs::default());
        let first = rx.recv().unwrap();
        assert!(start.elapsed() < Duration::from_millis(200), "fast task should stream early");
        match first {
            RunnerEvent::Done { task_id, .. } => assert_ne!(task_id, "slow"),
            _ => panic!("expected Done"),
        }
        // drain
        while rx.recv().is_ok() {}
    }

    #[test]
    fn timeout_skips_stuck_tasks() {
        let model = MockModel::new(6, Duration::from_millis(500), 0.0);
        let pool = Arc::new(ThreadPool::new("t", 2));
        let runner = WorkflowRunner::new(
            pool,
            RunnerConfig {
                timeout: Duration::from_millis(60),
                max_attempts: 1,
                retry_delay: Duration::ZERO,
                seed: 0,
            },
        );
        let (_, stats) = runner.run_collect(
            math_tasks(2),
            Arc::new(WorkflowRegistry::with_builtins()),
            Arc::new(model),
            Arc::new(Tokenizer::new()),
            SamplingArgs::default(),
        );
        assert_eq!(stats.timeouts, 2, "{stats:?}");
    }
}
