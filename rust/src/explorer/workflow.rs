//! Workflows: the single abstraction a user implements to adapt the
//! framework to a new scenario (paper §2.2, §3.1).
//!
//! Built-ins mirror the paper's examples:
//! * [`MathWorkflow`] — single-turn verifiable math (Listing 1).
//! * [`AlfworldWorkflow`] — multi-turn ReAct-style episodes compacted into
//!   one masked sequence (Listing 2).
//! * [`ReflectOnceWorkflow`] — experience synthesis with environmental
//!   feedback (Listing 3): K rollouts, verify, reflect, keep the corrected
//!   answer as an SFT-style experience.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::buffer::{Experience, Source};
use crate::envs::alfworld::{parse_action, AlfworldEnv};
use crate::envs::math::{format_score, verify};
use crate::tokenizer::{Tokenizer, SEP};
use crate::util::json::Value;
use crate::util::rng::Rng;

use super::generation::{GenOutput, RolloutModel, SamplingArgs};

/// A rollout task (the paper's Task: raw payload + rollout arguments).
#[derive(Debug, Clone)]
pub struct Task {
    pub id: String,
    pub workflow: String,
    pub payload: Value,
    pub difficulty: f64,
    /// Rollouts per task (GRPO group size).
    pub repeat_times: usize,
}

impl Task {
    pub fn new(id: &str, workflow: &str, payload: Value) -> Task {
        Task { id: id.to_string(), workflow: workflow.to_string(), payload, difficulty: 0.0, repeat_times: 1 }
    }

    /// Stable group id for GRPO advantage grouping.
    pub fn group_id(&self) -> u64 {
        self.id.bytes().fold(0xcbf29ce484222325u64, |h, b| (h ^ b as u64).wrapping_mul(0x100000001b3))
    }

    pub fn payload_str(&self, key: &str) -> Result<&str> {
        self.payload.get(key).and_then(Value::as_str).with_context(|| format!("task payload '{key}'"))
    }
}

pub struct WorkflowCtx<'a> {
    pub model: &'a dyn RolloutModel,
    pub tokenizer: &'a Tokenizer,
    pub task: &'a Task,
    pub sampling: SamplingArgs,
    pub rng: Rng,
}

impl<'a> WorkflowCtx<'a> {
    /// Turn-aware chat entry point for multi-turn workflows: tags the
    /// request with an episode session key so the service-side
    /// prefix-reuse cache can route the turn to the replica holding the
    /// episode's KV prefix and resume its parked session instead of
    /// re-prefilling the whole transcript (paper §2.2).  Endpoints
    /// without a cache (direct engine handles, mocks) ignore the tag,
    /// so opting in never changes untagged behavior.
    pub fn chat_turn(&self, session_key: u64, prompt: &[i32]) -> Result<GenOutput> {
        // the session key doubles as the episode's trace id: every span
        // of this episode (across turns, replicas and retries) shares
        // one timeline when observability is on
        let args = SamplingArgs {
            session: Some(session_key),
            trace: session_key,
            ..self.sampling.clone()
        };
        let mut outs = self.model.chat(prompt, 1, &args)?;
        anyhow::ensure!(!outs.is_empty(), "model returned no output for turn");
        Ok(outs.remove(0))
    }

    /// Turn a single-turn GenOutput into an Experience.
    pub fn experience_from_output(&self, out: &GenOutput, reward: f32) -> Experience {
        let mut e = Experience {
            id: 0,
            task_id: self.task.id.clone(),
            group: self.task.group_id(),
            tokens: out.tokens.clone(),
            prompt_len: out.prompt_len,
            logprobs: out.logprobs.clone(),
            loss_mask: out.loss_mask.clone(),
            reward,
            ready: true,
            source: Source::Explorer,
            // the exact serving version stamped on the output, not the
            // endpoint's current version (a rolling sync can land
            // between generation and here)
            model_version: out.version,
            parent_id: None,
            utility: 0.0,
            reuse_count: 0,
            metadata: Value::Object(vec![]),
        };
        let resp = self.tokenizer.decode_response(&out.tokens, out.prompt_len);
        e.set_meta("response", Value::str(resp));
        e.set_meta("finished", Value::Bool(out.finished));
        e
    }
}

pub trait Workflow: Send + Sync {
    fn name(&self) -> &'static str;
    /// The QoS request class this workflow's rollouts run under
    /// (DESIGN.md §11).  The runner stamps it on the per-task sampling
    /// unless the caller already tagged a non-default class (the eval
    /// driver tags `Eval`); latency-sensitive human-in-the-loop
    /// workflows override this to `Interactive`.
    fn class(&self) -> crate::qos::RequestClass {
        crate::qos::RequestClass::TrainRollout
    }
    fn run(&self, ctx: &mut WorkflowCtx) -> Result<Vec<Experience>>;
}

// ---------------------------------------------------------------------------
// registry

#[derive(Default)]
pub struct WorkflowRegistry {
    map: HashMap<String, Arc<dyn Workflow>>,
}

impl WorkflowRegistry {
    pub fn new() -> WorkflowRegistry {
        Self::default()
    }

    /// All built-in workflows registered (the library default).
    pub fn with_builtins() -> WorkflowRegistry {
        let mut r = Self::new();
        r.register(Arc::new(MathWorkflow { quality_bonus: 0.0 }));
        r.register(Arc::new(AlfworldWorkflow::default()));
        r.register(Arc::new(ReflectOnceWorkflow { k_rollouts: 4 }));
        r
    }

    pub fn register(&mut self, wf: Arc<dyn Workflow>) {
        self.map.insert(wf.name().to_string(), wf);
    }

    pub fn get(&self, name: &str) -> Result<Arc<dyn Workflow>> {
        self.map.get(name).cloned().with_context(|| format!("workflow '{name}' not registered"))
    }

    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.map.keys().cloned().collect();
        v.sort();
        v
    }
}

// ---------------------------------------------------------------------------
// built-in: single-turn math (paper Listing 1)

pub struct MathWorkflow {
    /// Optional small format-quality bonus added to the rule reward
    /// (the static flavor; the dynamic version lives in data pipelines).
    pub quality_bonus: f32,
}

impl Workflow for MathWorkflow {
    fn name(&self) -> &'static str {
        "math"
    }

    fn run(&self, ctx: &mut WorkflowCtx) -> Result<Vec<Experience>> {
        let question = ctx.task.payload_str("question")?;
        let answer: i64 = ctx.task.payload_str("answer")?.parse().context("answer must be integer")?;
        let prompt = ctx.tokenizer.encode_prompt(question);
        let outs = ctx.model.chat(&prompt, ctx.task.repeat_times.max(1), &ctx.sampling)?;
        let mut experiences = Vec::with_capacity(outs.len());
        for out in &outs {
            let resp = ctx.tokenizer.decode_response(&out.tokens, out.prompt_len);
            let mut reward = verify(&resp, answer);
            if self.quality_bonus > 0.0 {
                reward += self.quality_bonus * format_score(&resp);
            }
            let mut e = ctx.experience_from_output(out, reward);
            e.set_meta("correct", Value::Bool(verify(&resp, answer) > 0.5));
            experiences.push(e);
        }
        Ok(experiences)
    }
}

// ---------------------------------------------------------------------------
// built-in: multi-turn grid-world (paper Listing 2)

pub struct AlfworldWorkflow {
    pub max_env_steps: usize,
    pub env_init_cost: Duration,
    /// Hard cap on the packed sequence (must fit the generation bucket's
    /// KV-cache length minus one response budget).
    pub max_seq_tokens: usize,
}

impl Default for AlfworldWorkflow {
    fn default() -> Self {
        AlfworldWorkflow { max_env_steps: 4, env_init_cost: Duration::ZERO, max_seq_tokens: 56 }
    }
}

impl Workflow for AlfworldWorkflow {
    fn name(&self) -> &'static str {
        "alfworld"
    }

    fn run(&self, ctx: &mut WorkflowCtx) -> Result<Vec<Experience>> {
        let seed = ctx.task.payload.get("seed").and_then(Value::as_f64).unwrap_or(0.0) as u64;
        // one env, reset (not re-created) per rollout — the paper's
        // environment-reuse optimization
        let mut env = AlfworldEnv::create(seed, self.max_env_steps, self.env_init_cost);
        let mut experiences = Vec::with_capacity(ctx.task.repeat_times);
        for rollout in 0..ctx.task.repeat_times.max(1) {
            if rollout > 0 {
                env.reset();
            }
            experiences.push(self.run_episode(ctx, &mut env, rollout)?);
        }
        Ok(experiences)
    }
}

impl AlfworldWorkflow {
    /// Stable per-episode session key: unique across tasks, rollouts and
    /// sampling seeds, stable across the turns of one episode — the
    /// handle the prefix-reuse cache parks and resumes KV sessions by.
    fn episode_key(ctx: &WorkflowCtx, rollout: usize) -> u64 {
        ctx.task
            .group_id()
            .rotate_left(13)
            .wrapping_add(ctx.sampling.seed)
            .wrapping_add((rollout as u64).wrapping_mul(0x9e3779b97f4a7c15))
    }

    /// `process_messages_to_experience`: the whole episode becomes ONE
    /// packed sequence; observation tokens are masked out, action tokens
    /// are trained on.
    fn run_episode(
        &self,
        ctx: &mut WorkflowCtx,
        env: &mut AlfworldEnv,
        rollout: usize,
    ) -> Result<Experience> {
        let tok = ctx.tokenizer;
        let goal = env.goal_text();
        let first_obs = env.observe();

        // running packed sequence
        let mut tokens = tok.encode_prompt(&format!("{goal} . {first_obs}"));
        let prompt_len = tokens.len();
        let mut logprobs = vec![0.0f32; prompt_len];
        let mut loss_mask = vec![0.0f32; prompt_len];

        let mut final_reward = -0.1f32;
        let mut rounds = 0usize;
        let mut done = false;
        // per-turn response budget
        let budget = ctx.sampling.max_new_tokens.max(4);
        // the episode's session key: every turn carries it so the
        // service can reuse the previous turn's KV instead of
        // re-prefilling the growing transcript
        let session_key = Self::episode_key(ctx, rollout);
        let mut served_version = ctx.model.weight_version();

        for _round in 0..self.max_env_steps {
            // the model continues the packed sequence
            let out = ctx.chat_turn(session_key, &tokens)?;
            served_version = out.version;
            // splice the response (tokens after the current prefix)
            let resp_start = out.prompt_len;
            let resp_tokens = &out.tokens[resp_start..];
            let resp_lp = &out.logprobs[resp_start..];
            tokens.extend_from_slice(resp_tokens);
            logprobs.extend_from_slice(resp_lp);
            loss_mask.extend(std::iter::repeat(1.0).take(resp_tokens.len()));

            let action_text = tok.decode_response(&out.tokens, resp_start);
            let action = parse_action(&action_text);
            let (obs, reward, is_done) = env.step(&action);
            rounds += 1;
            final_reward = reward;
            done = is_done;
            if done {
                break;
            }
            // append the observation (masked) + SEP
            let mut obs_tokens = tok.encode(&obs);
            obs_tokens.push(SEP);
            tokens.extend_from_slice(&obs_tokens);
            logprobs.extend(std::iter::repeat(0.0).take(obs_tokens.len()));
            loss_mask.extend(std::iter::repeat(0.0).take(obs_tokens.len()));

            // stop if the next turn can't fit within the sequence budget
            if tokens.len() + budget + 8 > self.max_seq_tokens {
                break;
            }
        }

        let mut e = Experience {
            id: 0,
            task_id: ctx.task.id.clone(),
            group: ctx.task.group_id(),
            prompt_len,
            reward: final_reward,
            ready: true,
            source: Source::Explorer,
            // last turn's exact serving stamp (see GenOutput::version)
            model_version: served_version,
            parent_id: None,
            utility: 0.0,
            reuse_count: 0,
            metadata: Value::Object(vec![]),
            tokens,
            logprobs,
            loss_mask,
        };
        e.set_meta("env_rounds", Value::int(rounds as i64));
        e.set_meta("env_done", Value::Bool(done));
        Ok(e)
    }
}

// ---------------------------------------------------------------------------
// built-in: experience synthesis via reflection (paper Listing 3)

pub struct ReflectOnceWorkflow {
    pub k_rollouts: usize,
}

impl Workflow for ReflectOnceWorkflow {
    fn name(&self) -> &'static str {
        "reflect_once"
    }

    fn run(&self, ctx: &mut WorkflowCtx) -> Result<Vec<Experience>> {
        let question = ctx.task.payload_str("question")?;
        let answer: i64 = ctx.task.payload_str("answer")?.parse()?;
        let tok = ctx.tokenizer;

        // Stage 1: K rollouts
        let prompt = tok.encode_prompt(question);
        let outs = ctx.model.chat(&prompt, self.k_rollouts, &ctx.sampling)?;

        // Stage 2: verification (environmental feedback, plain text)
        let verdicts: Vec<(String, bool)> = outs
            .iter()
            .map(|o| {
                let resp = tok.decode_response(&o.tokens, o.prompt_len);
                let ok = verify(&resp, answer) > 0.5;
                (resp, ok)
            })
            .collect();

        // Stage 3: reflection — feed back attempts + verdicts
        let mut reflection = format!("question {question} .");
        for (resp, ok) in verdicts.iter().take(3) {
            let adj = if *ok { "yes" } else { "no" };
            reflection.push_str(&format!(" answer {resp} ok {adj} ."));
        }
        reflection.push_str(" think step and answer");
        let refl_prompt = tok.encode_prompt(&reflection);
        let refl_outs = ctx.model.chat(&refl_prompt, 1, &ctx.sampling)?;
        let refl = &refl_outs[0];
        let refl_text = tok.decode_response(&refl.tokens, refl.prompt_len);

        // keep the synthesized experience only if the reflection is correct —
        // it becomes SFT-style data (Source::Synthetic) for the trainer
        let mut experiences = Vec::new();
        if verify(&refl_text, answer) > 0.5 {
            let mut e = ctx.experience_from_output(refl, 1.0);
            e.source = Source::Synthetic;
            e.set_meta("synthesized", Value::Bool(true));
            e.set_meta("k_attempts", Value::int(self.k_rollouts as i64));
            experiences.push(e);
        }
        Ok(experiences)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::explorer::generation::MockModel;
    use crate::tokenizer::EOS;

    fn ctx_parts() -> (Tokenizer, SamplingArgs) {
        (Tokenizer::new(), SamplingArgs { max_new_tokens: 8, ..Default::default() })
    }

    fn math_task(q: &str, a: i64, n: usize) -> Task {
        let mut t = Task::new(
            "t1",
            "math",
            Value::obj(vec![("question", Value::str(q)), ("answer", Value::str(a.to_string()))]),
        );
        t.repeat_times = n;
        t
    }

    /// Mock that always answers "7".
    fn mock_always_7(tok: &Tokenizer) -> MockModel {
        let resp = tok.encode("7");
        MockModel::new(1, Duration::ZERO, 0.0).with_response(move |_, _| {
            let mut r = resp.clone();
            r.push(EOS);
            r
        })
    }

    #[test]
    fn math_workflow_rewards_correct_answers() {
        let (tok, sampling) = ctx_parts();
        let model = mock_always_7(&tok);
        let task = math_task("what is 3 + 4 ?", 7, 3);
        let mut ctx = WorkflowCtx { model: &model, tokenizer: &tok, task: &task, sampling, rng: Rng::new(1) };
        let wf = MathWorkflow { quality_bonus: 0.0 };
        let exps = wf.run(&mut ctx).unwrap();
        assert_eq!(exps.len(), 3);
        for e in &exps {
            assert_eq!(e.reward, 1.0);
            assert_eq!(e.group, task.group_id());
            assert!(e.response_len() > 0);
            assert_eq!(e.metadata.get("correct").unwrap().as_bool(), Some(true));
        }
    }

    #[test]
    fn math_workflow_zero_reward_for_wrong() {
        let (tok, sampling) = ctx_parts();
        let model = mock_always_7(&tok);
        let task = math_task("what is 1 + 1 ?", 2, 2);
        let mut ctx = WorkflowCtx { model: &model, tokenizer: &tok, task: &task, sampling, rng: Rng::new(2) };
        let exps = MathWorkflow { quality_bonus: 0.0 }.run(&mut ctx).unwrap();
        assert!(exps.iter().all(|e| e.reward == 0.0));
    }

    #[test]
    fn alfworld_workflow_packs_episode_with_masks() {
        let (tok, sampling) = ctx_parts();
        // model that always says "look" — episode runs to max steps
        let look = tok.encode("look");
        let model = MockModel::new(3, Duration::ZERO, 0.0).with_response(move |_, _| {
            let mut r = look.clone();
            r.push(EOS);
            r
        });
        let mut task = Task::new("a1", "alfworld", Value::obj(vec![("seed", Value::int(5))]));
        task.repeat_times = 2;
        let mut ctx =
            WorkflowCtx { model: &model, tokenizer: &tok, task: &task, sampling, rng: Rng::new(3) };
        let wf = AlfworldWorkflow { max_env_steps: 3, env_init_cost: Duration::ZERO, max_seq_tokens: 200 };
        let exps = wf.run(&mut ctx).unwrap();
        assert_eq!(exps.len(), 2);
        for e in &exps {
            assert_eq!(e.tokens.len(), e.loss_mask.len());
            assert_eq!(e.tokens.len(), e.logprobs.len());
            // prompt masked out
            assert!(e.loss_mask[..e.prompt_len].iter().all(|&m| m == 0.0));
            // some action tokens trained on, some obs tokens masked
            let trained = e.loss_mask.iter().filter(|&&m| m > 0.0).count();
            let masked_after_prompt =
                e.loss_mask[e.prompt_len..].iter().filter(|&&m| m == 0.0).count();
            assert!(trained > 0);
            assert!(masked_after_prompt > 0, "obs tokens should be masked");
            assert_eq!(e.reward, -0.1); // never solved by 'look'
            assert_eq!(e.meta_f64("env_rounds"), Some(3.0));
        }
    }

    #[test]
    fn alfworld_expert_plan_gets_full_reward() {
        let (tok, sampling) = ctx_parts();
        // a "model" that replays the optimal plan step by step
        let seed = 11u64;
        let env_probe = AlfworldEnv::create(seed, 8, Duration::ZERO);
        let plan: Vec<String> =
            env_probe.optimal_plan().iter().map(AlfworldEnv::action_text).collect();
        let counter = std::sync::atomic::AtomicUsize::new(0);
        let tok2 = Tokenizer::new();
        let model = MockModel::new(4, Duration::ZERO, 0.0).with_response(move |_, _| {
            let i = counter.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
            let text = plan.get(i.min(plan.len() - 1)).cloned().unwrap_or_else(|| "look".into());
            let mut r = tok2.encode(&text);
            r.push(EOS);
            r
        });
        let task = Task::new("a2", "alfworld", Value::obj(vec![("seed", Value::int(seed as i64))]));
        let mut ctx =
            WorkflowCtx { model: &model, tokenizer: &tok, task: &task, sampling, rng: Rng::new(4) };
        let wf = AlfworldWorkflow { max_env_steps: 8, env_init_cost: Duration::ZERO, max_seq_tokens: 200 };
        let exps = wf.run(&mut ctx).unwrap();
        assert_eq!(exps[0].reward, 1.0);
        assert_eq!(exps[0].metadata.get("env_done").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn reflect_once_synthesizes_only_correct() {
        let (tok, sampling) = ctx_parts();
        let model = mock_always_7(&tok);
        // answer matches -> one synthetic experience
        let task = math_task("what is 3 + 4 ?", 7, 1);
        let mut ctx = WorkflowCtx { model: &model, tokenizer: &tok, task: &task, sampling: sampling.clone(), rng: Rng::new(5) };
        let wf = ReflectOnceWorkflow { k_rollouts: 2 };
        let exps = wf.run(&mut ctx).unwrap();
        assert_eq!(exps.len(), 1);
        assert_eq!(exps[0].source, Source::Synthetic);
        assert_eq!(exps[0].reward, 1.0);
        // answer wrong -> nothing kept
        let task2 = math_task("what is 1 + 1 ?", 2, 1);
        let mut ctx2 = WorkflowCtx { model: &model, tokenizer: &tok, task: &task2, sampling, rng: Rng::new(6) };
        assert!(wf.run(&mut ctx2).unwrap().is_empty());
    }

    #[test]
    fn registry_builtins() {
        let r = WorkflowRegistry::with_builtins();
        assert!(r.get("math").is_ok());
        assert!(r.get("alfworld").is_ok());
        assert!(r.get("reflect_once").is_ok());
        assert!(r.get("nope").is_err());
        assert_eq!(r.names().len(), 3);
    }

    #[test]
    fn group_ids_stable_and_distinct() {
        let t1 = Task::new("a", "math", Value::Object(vec![]));
        let t1b = Task::new("a", "math", Value::Object(vec![]));
        let t2 = Task::new("b", "math", Value::Object(vec![]));
        assert_eq!(t1.group_id(), t1b.group_id());
        assert_ne!(t1.group_id(), t2.group_id());
    }
}
