//! The rollout engine (vLLM stand-in): batched KV-cache generation with
//! per-sequence positions, streaming-friendly sessions, and multi-turn
//! continuation that *feeds* environment tokens through the decode path
//! instead of re-prefilling (the paper's avoid-recomputation optimization
//! for multi-turn workflows, §2.2).
//!
//! Concurrency: rollouts run under a read lock on the weights, so many
//! runner threads generate in parallel; weight sync takes the write lock —
//! exactly the "service pauses while the explorer updates weights" window
//! that the multi-explorer mode exists to hide.

use std::sync::{Arc, RwLock};

use anyhow::{ensure, Result};

use crate::model::{ParamStore, WeightSnapshot, WeightSync, WeightUpdate};
use crate::runtime::{GenerationState, ModelEngine, Tensor};
use crate::tokenizer::{BOS, EOS};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct SamplingArgs {
    pub temperature: f32,
    pub top_k: usize,
    pub top_p: f32,
    pub max_new_tokens: usize,
    pub seed: u64,
    /// Episode session key for the prefix-reuse cache: follow-up turns
    /// that share a key can resume a parked KV session on the replica
    /// that served the prefix (service-side; direct engine handles and
    /// mocks ignore it, so tagging never changes untagged behavior).
    pub session: Option<u64>,
    /// Episode trace id for span recording (0 = untraced).  Sampling
    /// never reads it; the service stamps it onto row jobs so every
    /// span of one episode shares a timeline.
    pub trace: u64,
    /// QoS traffic class (train / eval / interactive).  Sampling never
    /// reads it; the service's fair scheduler, per-class deadlines and
    /// class-tagged telemetry do (DESIGN.md §11).  Defaults to
    /// `TrainRollout`, so class-unaware callers behave as before.
    pub class: crate::qos::RequestClass,
}

impl Default for SamplingArgs {
    fn default() -> Self {
        SamplingArgs {
            temperature: 1.0,
            top_k: 0,
            top_p: 1.0,
            max_new_tokens: 16,
            seed: 0,
            session: None,
            trace: 0,
            class: crate::qos::RequestClass::TrainRollout,
        }
    }
}

#[derive(Debug, Clone)]
pub struct GenOutput {
    /// Full sequence: prompt + generated tokens (EOS included if emitted).
    pub tokens: Vec<i32>,
    pub prompt_len: usize,
    /// Per-token log-probs aligned with `tokens` (0 for prompt positions).
    pub logprobs: Vec<f32>,
    /// Loss mask aligned with `tokens` (1 for sampled tokens).
    pub loss_mask: Vec<f32>,
    /// True if the sequence ended with EOS (vs budget exhaustion).
    pub finished: bool,
    /// Exact weight version that served this output, captured at
    /// session/chunk boundaries — stays true even when a rolling sync
    /// lands mid-session (the cache invalidates off the same stamp).
    pub version: u64,
}

/// The interface workflows talk to (the paper's ModelWrapper).
pub trait RolloutModel: Send + Sync {
    /// Generate `n` independent completions of `prompt`.
    fn chat(&self, prompt: &[i32], n: usize, args: &SamplingArgs) -> Result<Vec<GenOutput>>;
    /// Version of the weights that will serve the next call.
    fn weight_version(&self) -> u64;
}

/// What the explorer needs from its model tier beyond [`RolloutModel`]:
/// the weight lifecycle.  Implemented by a direct [`GenerationEngine`]
/// handle (the seed wiring), by the rollout service's replica pool
/// (`service::RolloutService`), and by [`MockModel`] for tests.
pub trait RolloutEndpoint: RolloutModel {
    /// Pull newer weights from the sync service if published.
    fn sync_weights(&self, sync: &dyn WeightSync) -> Result<bool>;
    /// Overwrite weights from a shared snapshot (initial load / bench
    /// over checkpoints).  The snapshot is borrowed, never copied: pool
    /// endpoints fan one snapshot out across every replica.
    fn set_weights(&self, snapshot: &WeightSnapshot, version: u64) -> Result<()>;
}

/// An in-flight generation batch (KV caches + per-row cursors).
pub struct Session {
    state: GenerationState,
    /// Next write position per row.
    pos: Vec<usize>,
    /// Accumulated full sequences per row.
    pub tokens: Vec<Vec<i32>>,
    pub logprobs: Vec<Vec<f32>>,
    pub loss_mask: Vec<Vec<f32>>,
    /// Rows that correspond to real prompts (batch padding rows are inactive).
    pub active: Vec<bool>,
    rngs: Vec<Rng>,
    cache_len: usize,
    /// Weight version that last wrote each row's KV (stamped at every
    /// prefill/feed/sample boundary while the params lock is held).
    versions: Vec<u64>,
}

impl Session {
    pub fn remaining_budget(&self, row: usize) -> usize {
        self.cache_len.saturating_sub(self.pos[row])
    }

    pub fn rows(&self) -> usize {
        self.pos.len()
    }

    /// Weight version that last served this row (exact, per chunk).
    pub fn row_version(&self, row: usize) -> u64 {
        self.versions[row]
    }

    /// Re-base a row as a fresh request continuing its transcript: every
    /// token accumulated so far becomes prompt context (logprob 0, loss
    /// mask 0), exactly what a cold re-chat of the transcript would
    /// produce.  Used by the parked-session resume path.
    pub fn rebase_row(&mut self, row: usize) {
        for v in self.logprobs[row].iter_mut() {
            *v = 0.0;
        }
        for v in self.loss_mask[row].iter_mut() {
            *v = 0.0;
        }
    }

    /// Re-seed one row's sampling RNG (the rollout service gives every
    /// request its own seed even when requests share a session).
    pub fn seed_row(&mut self, row: usize, seed: u64) {
        self.rngs[row] = Rng::with_stream(seed, 0x5eed ^ row as u64);
    }

    pub fn output(&self, row: usize, prompt_len: usize, finished: bool) -> GenOutput {
        GenOutput {
            tokens: self.tokens[row].clone(),
            prompt_len,
            logprobs: self.logprobs[row].clone(),
            loss_mask: self.loss_mask[row].clone(),
            finished,
            version: self.versions[row],
        }
    }
}

pub struct GenerationEngine {
    engine: Arc<ModelEngine>,
    params: RwLock<ParamStore>,
}

impl GenerationEngine {
    pub fn new(engine: Arc<ModelEngine>, params: ParamStore) -> GenerationEngine {
        GenerationEngine { engine, params: RwLock::new(params) }
    }

    pub fn engine(&self) -> &Arc<ModelEngine> {
        &self.engine
    }

    pub fn params_version(&self) -> u64 {
        self.params.read().unwrap().version()
    }

    /// Pull newer weights if available and apply them with minimal
    /// serving stall (see [`apply_update`](Self::apply_update)).
    pub fn try_sync(&self, sync: &dyn WeightSync) -> Result<bool> {
        let current = self.params_version();
        if let Some(update) = sync.fetch_if_newer(current)? {
            let applied = self.apply_update(&update)?;
            if applied {
                crate::log_debug!(
                    "explorer",
                    "synced weights to v{} (step {})",
                    update.version,
                    update.step
                );
            }
            return Ok(applied);
        }
        Ok(false)
    }

    /// Low-stall delta apply of a published update.
    ///
    /// Three phases: *plan* under the read lock (diff fingerprints,
    /// rollouts keep running), *prepare* with no lock held (rebuild only
    /// the dirty leaves, large ones in parallel on the shared prepare
    /// pool), *commit* under the write lock (swap literal handles in).
    /// In-flight rollouts therefore only ever wait for the pointer
    /// swaps, not for the full-model rebuild the old path did under the
    /// write lock.  Returns false when the store already reached
    /// `update.version` (another syncer raced us there).
    pub fn apply_update(&self, update: &WeightUpdate) -> Result<bool> {
        let dirty = {
            let guard = self.params.read().unwrap();
            if guard.version() >= update.version {
                return Ok(false);
            }
            guard.plan_delta(&update.snapshot)?
        };
        let prepared =
            ParamStore::prepare_leaves(&self.engine.model, &update.snapshot, &dirty)?;
        let mut guard = self.params.write().unwrap();
        if guard.version() >= update.version {
            return Ok(false);
        }
        guard.commit_prepared(&update.snapshot, prepared, update.version)?;
        Ok(true)
    }

    /// Overwrite weights from a shared snapshot (initial load).  Also a
    /// delta apply: leaves whose fingerprints already match are kept.
    pub fn set_weights(&self, snapshot: &WeightSnapshot, version: u64) -> Result<()> {
        self.params.write().unwrap().apply_snapshot(snapshot, version).map(|_| ())
    }

    /// Leaves skipped across all weight applies so far (delta-apply
    /// effectiveness; see `ParamStore::fingerprint_hits`).
    pub fn fingerprint_hits(&self) -> u64 {
        self.params.read().unwrap().fingerprint_hits()
    }

    pub fn snapshot_weights(&self) -> Result<Vec<Vec<f32>>> {
        self.params.read().unwrap().snapshot()
    }

    /// Start a session for up to `gen_batch` prompts (padded internally).
    pub fn start_session(&self, prompts: &[Vec<i32>], seed: u64) -> Result<Session> {
        let (b, tp, cache) = self.engine.gen_shape();
        ensure!(prompts.len() <= b, "session supports at most {b} prompts");
        ensure!(!prompts.is_empty(), "empty prompt set");
        let mut tokens = Tensor::zeros(crate::runtime::DType::I32, &[b, tp]);
        let mut lens = vec![1i32; b];
        let mut seqs: Vec<Vec<i32>> = Vec::with_capacity(b);
        let mut active = vec![false; b];
        {
            let data = match &mut tokens {
                Tensor::I32 { data, .. } => data,
                _ => unreachable!(),
            };
            for row in 0..b {
                let prompt: &[i32] = if row < prompts.len() {
                    active[row] = true;
                    &prompts[row]
                } else {
                    &[BOS] // padding row
                };
                let plen = prompt.len().min(tp);
                ensure!(plen >= 1, "prompt must be non-empty");
                data[row * tp..row * tp + plen].copy_from_slice(&prompt[..plen]);
                lens[row] = plen as i32;
                seqs.push(prompt[..plen].to_vec());
            }
        }
        let lens_t = Tensor::from_i32(vec![b], lens.clone());
        let guard = self.params.read().unwrap();
        let version = guard.version();
        let state = self.engine.prefill(&guard, &tokens, &lens_t)?;
        drop(guard);
        let pos: Vec<usize> = lens.iter().map(|&l| l as usize).collect();
        let zero_lp: Vec<Vec<f32>> = seqs.iter().map(|s| vec![0.0; s.len()]).collect();
        let zero_mask: Vec<Vec<f32>> = seqs.iter().map(|s| vec![0.0; s.len()]).collect();
        let mut rngs = Vec::with_capacity(b);
        for row in 0..b {
            rngs.push(Rng::with_stream(seed.wrapping_add(row as u64), 0x5eed + row as u64));
        }
        Ok(Session {
            state,
            pos,
            tokens: seqs,
            logprobs: zero_lp,
            loss_mask: zero_mask,
            active,
            rngs,
            cache_len: cache,
            versions: vec![version; b],
        })
    }

    /// Teacher-force environment/observation tokens into the caches (mask
    /// 0, logprob 0).  Rows with shorter inputs re-feed their last token at
    /// a frozen position, which rewrites the same K/V and is a no-op.
    pub fn feed(&self, session: &mut Session, row_tokens: &[Vec<i32>]) -> Result<()> {
        let b = session.pos.len();
        ensure!(row_tokens.len() == b, "feed wants {b} rows");
        let max_len = row_tokens.iter().map(Vec::len).max().unwrap_or(0);
        if max_len == 0 {
            return Ok(());
        }
        for (row, toks) in row_tokens.iter().enumerate() {
            // rows with no input only re-write their last position; the
            // overflow check applies to rows actually receiving tokens
            if toks.is_empty() {
                continue;
            }
            ensure!(
                session.pos[row] + toks.len() < session.cache_len,
                "row {row} overflows cache ({} + {})",
                session.pos[row],
                toks.len()
            );
        }
        let guard = self.params.read().unwrap();
        let version = guard.version();
        for (row, toks) in row_tokens.iter().enumerate() {
            if !toks.is_empty() {
                session.versions[row] = version;
            }
        }
        for step in 0..max_len {
            let mut step_tokens = Vec::with_capacity(b);
            let mut step_pos = Vec::with_capacity(b);
            for row in 0..b {
                if step < row_tokens[row].len() {
                    let t = row_tokens[row][step];
                    step_tokens.push(t);
                    step_pos.push(session.pos[row] as i32);
                    session.pos[row] += 1;
                    session.tokens[row].push(t);
                    session.logprobs[row].push(0.0);
                    session.loss_mask[row].push(0.0);
                } else {
                    // idempotent re-write of the last token at its position
                    let last = *session.tokens[row].last().unwrap_or(&BOS);
                    step_tokens.push(last);
                    step_pos.push((session.pos[row].saturating_sub(1)) as i32);
                }
            }
            let tok_t = Tensor::from_i32(vec![b], step_tokens);
            let pos_t = Tensor::from_i32(vec![b], step_pos);
            self.engine.decode(&guard, &mut session.state, &tok_t, &pos_t)?;
        }
        Ok(())
    }

    /// Continuous-batching slot refill: reset `row` to serve a fresh
    /// prompt mid-session while the other rows keep their caches.  The
    /// new prompt streams through the decode path at positions starting
    /// from 0 — sound because decode masks attention to cache positions
    /// `<= pos` and overwrites position `pos` before attending, so the
    /// retired request's stale K/V beyond the new prompt is never
    /// observed (see `decode_step` in `python/compile/model.py`).
    pub fn restart_row(
        &self,
        session: &mut Session,
        row: usize,
        prompt: &[i32],
        seed: u64,
    ) -> Result<()> {
        ensure!(row < session.pos.len(), "row {row} out of range");
        ensure!(!prompt.is_empty(), "prompt must be non-empty");
        ensure!(
            prompt.len() + 1 < session.cache_len,
            "prompt ({} tokens) overflows cache ({})",
            prompt.len(),
            session.cache_len
        );
        session.pos[row] = 0;
        session.tokens[row].clear();
        session.logprobs[row].clear();
        session.loss_mask[row].clear();
        session.active[row] = true;
        session.seed_row(row, seed);
        let mut rows: Vec<Vec<i32>> = vec![Vec::new(); session.pos.len()];
        rows[row] = prompt.to_vec();
        self.feed(session, &rows)
    }

    /// Parked-session resume: extend row `row` — whose KV already holds
    /// a previous turn's transcript — with the new turn's `delta` tokens
    /// through the masked decode path, the same mechanism that makes
    /// [`restart_row`](Self::restart_row) sound.  The row's accumulated
    /// transcript is re-based as prompt context (logprob/mask zeroed)
    /// and its sampler re-seeded, so the continuation is byte-identical
    /// to a cold re-chat of `transcript + delta` under the same weights:
    /// the prefix KV was written by the same prefill/decode sequence a
    /// cold start would replay, and only the re-prefill is skipped.
    pub fn extend_row(
        &self,
        session: &mut Session,
        row: usize,
        delta: &[i32],
        seed: u64,
    ) -> Result<()> {
        ensure!(row < session.pos.len(), "row {row} out of range");
        session.rebase_row(row);
        session.active[row] = true;
        session.seed_row(row, seed);
        if delta.is_empty() {
            // turn retry with an identical transcript: the cache already
            // holds everything; the row's logits are its last token's
            return Ok(());
        }
        let mut rows: Vec<Vec<i32>> = vec![Vec::new(); session.pos.len()];
        rows[row] = delta.to_vec();
        self.feed(session, &rows)
    }

    /// Sample up to `max_new` tokens per active row, stopping rows at EOS.
    /// Returns which rows finished with EOS.
    pub fn sample(
        &self,
        session: &mut Session,
        args: &SamplingArgs,
        rows: &[bool],
    ) -> Result<Vec<bool>> {
        let b = session.pos.len();
        ensure!(rows.len() == b, "rows mask arity");
        let mut live: Vec<bool> = rows.to_vec();
        let mut finished = vec![false; b];
        let guard = self.params.read().unwrap();
        // chunk-boundary version stamp: the lock is held for the whole
        // call, so every token this call samples is served by exactly
        // this version
        let version = guard.version();
        for (row, &on) in rows.iter().enumerate() {
            if on {
                session.versions[row] = version;
            }
        }
        for _ in 0..args.max_new_tokens {
            if !live.iter().any(|&l| l) {
                break;
            }
            // sample from the current logits
            let mut step_tokens = Vec::with_capacity(b);
            let mut step_pos = Vec::with_capacity(b);
            for row in 0..b {
                if live[row] && session.pos[row] < session.cache_len {
                    let logits = session.state.logits.row_f32(row)?;
                    let tok = session.rngs[row].sample_logits(
                        logits,
                        args.temperature,
                        args.top_k,
                        args.top_p,
                    ) as i32;
                    let lp = log_softmax_at(logits, tok as usize);
                    session.tokens[row].push(tok);
                    session.logprobs[row].push(lp);
                    session.loss_mask[row].push(1.0);
                    step_tokens.push(tok);
                    step_pos.push(session.pos[row] as i32);
                    session.pos[row] += 1;
                    if tok == EOS {
                        finished[row] = true;
                        live[row] = false;
                    } else if session.pos[row] >= session.cache_len {
                        live[row] = false;
                    }
                } else {
                    live[row] = false;
                    let last = *session.tokens[row].last().unwrap_or(&BOS);
                    step_tokens.push(last);
                    step_pos.push((session.pos[row].saturating_sub(1)) as i32);
                }
            }
            // the sampled tokens must enter the cache before the next
            // sampling iteration; skip the trailing decode once all rows
            // are done.
            if live.iter().any(|&l| l) {
                let tok_t = Tensor::from_i32(vec![b], step_tokens);
                let pos_t = Tensor::from_i32(vec![b], step_pos);
                self.engine.decode(&guard, &mut session.state, &tok_t, &pos_t)?;
            }
        }
        Ok(finished)
    }

    /// Single-turn batched generation: the `chat` fast path.
    ///
    /// Prompts longer than the prefill bucket are handled by prefixing the
    /// first `Tp` tokens through prefill and streaming the remainder
    /// through the decode path (`feed`), so multi-turn workflows whose
    /// packed context outgrows the prompt bucket keep working — bounded
    /// only by the KV-cache length.
    pub fn generate(&self, prompts: &[Vec<i32>], args: &SamplingArgs) -> Result<Vec<GenOutput>> {
        let (b, tp, cache) = self.engine.gen_shape();
        let mut outputs = Vec::with_capacity(prompts.len());
        for chunk in prompts.chunks(b) {
            // clamp prompts that cannot fit the cache at all
            let clamped: Vec<Vec<i32>> = chunk
                .iter()
                .map(|p| {
                    let max = cache.saturating_sub(2);
                    if p.len() > max {
                        p[..max].to_vec()
                    } else {
                        p.clone()
                    }
                })
                .collect();
            let heads: Vec<Vec<i32>> =
                clamped.iter().map(|p| p[..p.len().min(tp)].to_vec()).collect();
            let mut session = self.start_session(&heads, args.seed.wrapping_add(outputs.len() as u64))?;
            let tails: Vec<Vec<i32>> = (0..session.pos.len())
                .map(|row| {
                    if row < clamped.len() && clamped[row].len() > tp {
                        clamped[row][tp..].to_vec()
                    } else {
                        Vec::new()
                    }
                })
                .collect();
            if tails.iter().any(|t| !t.is_empty()) {
                self.feed(&mut session, &tails)?;
            }
            let rows = session.active.clone();
            let finished = self.sample(&mut session, args, &rows)?;
            for (row, prompt) in clamped.iter().enumerate() {
                let plen = prompt.len().min(session.tokens[row].len());
                outputs.push(session.output(row, plen, finished[row]));
            }
        }
        Ok(outputs)
    }
}

impl RolloutModel for GenerationEngine {
    fn chat(&self, prompt: &[i32], n: usize, args: &SamplingArgs) -> Result<Vec<GenOutput>> {
        let prompts: Vec<Vec<i32>> = (0..n).map(|_| prompt.to_vec()).collect();
        // vary seeds across the n rollouts via the chunk offset in generate()
        self.generate(&prompts, args)
    }

    fn weight_version(&self) -> u64 {
        self.params_version()
    }
}

impl RolloutEndpoint for GenerationEngine {
    fn sync_weights(&self, sync: &dyn WeightSync) -> Result<bool> {
        self.try_sync(sync)
    }

    fn set_weights(&self, snapshot: &WeightSnapshot, version: u64) -> Result<()> {
        GenerationEngine::set_weights(self, snapshot, version)
    }
}

fn log_softmax_at(logits: &[f32], idx: usize) -> f32 {
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let lse: f32 = logits.iter().map(|&x| (x - max).exp()).sum::<f32>().ln() + max;
    logits[idx] - lse
}

// ---------------------------------------------------------------------------
// Mock model for unit tests of runners/pipelines (no PJRT involved).

/// Scripted rollout model: configurable latency, failure rate and response
/// text; used by runner/coordinator/service unit tests and failure
/// injection.  `fail_rate` and `latency` are settable at runtime so
/// circuit-breaker tests can break a replica and heal it, and fairness /
/// migration tests can slow a replica mid-run deterministically.
pub struct MockModel {
    latency_ns: std::sync::atomic::AtomicU64,
    fail_rate: std::sync::atomic::AtomicU64,
    pub respond: Box<dyn Fn(&[i32], &mut Rng) -> Vec<i32> + Send + Sync>,
    rng: std::sync::Mutex<Rng>,
    version: std::sync::atomic::AtomicU64,
}

impl MockModel {
    pub fn new(seed: u64, latency: std::time::Duration, fail_rate: f64) -> MockModel {
        MockModel {
            latency_ns: std::sync::atomic::AtomicU64::new(latency.as_nanos() as u64),
            fail_rate: std::sync::atomic::AtomicU64::new(fail_rate.to_bits()),
            respond: Box::new(|_, rng| {
                let n = 1 + rng.below(4) as usize;
                let mut out: Vec<i32> = (0..n).map(|_| 100 + rng.below(20) as i32).collect();
                out.push(EOS);
                out
            }),
            rng: std::sync::Mutex::new(Rng::new(seed)),
            version: std::sync::atomic::AtomicU64::new(0),
        }
    }

    pub fn with_response(mut self, f: impl Fn(&[i32], &mut Rng) -> Vec<i32> + Send + Sync + 'static) -> Self {
        self.respond = Box::new(f);
        self
    }

    pub fn set_version(&self, v: u64) {
        self.version.store(v, std::sync::atomic::Ordering::SeqCst);
    }

    pub fn latency(&self) -> std::time::Duration {
        std::time::Duration::from_nanos(self.latency_ns.load(std::sync::atomic::Ordering::SeqCst))
    }

    /// Change the injected per-request latency (fairness and migration
    /// tests slow one replica mid-run to force overload/starvation
    /// scenarios deterministically).
    pub fn set_latency(&self, latency: std::time::Duration) {
        self.latency_ns
            .store(latency.as_nanos() as u64, std::sync::atomic::Ordering::SeqCst);
    }

    pub fn fail_rate(&self) -> f64 {
        f64::from_bits(self.fail_rate.load(std::sync::atomic::Ordering::SeqCst))
    }

    /// Change the injected failure probability (quarantine-recovery tests
    /// break a replica, then heal it mid-run).
    pub fn set_fail_rate(&self, rate: f64) {
        self.fail_rate.store(rate.to_bits(), std::sync::atomic::Ordering::SeqCst);
    }
}

impl RolloutModel for MockModel {
    fn chat(&self, prompt: &[i32], n: usize, _args: &SamplingArgs) -> Result<Vec<GenOutput>> {
        let latency = self.latency();
        if !latency.is_zero() {
            std::thread::sleep(latency);
        }
        let fail_rate = self.fail_rate();
        let mut rng = self.rng.lock().unwrap();
        if fail_rate > 0.0 && rng.bool(fail_rate) {
            anyhow::bail!("mock model transient failure");
        }
        let mut outs = Vec::with_capacity(n);
        for _ in 0..n {
            let resp = (self.respond)(prompt, &mut rng);
            let mut tokens = prompt.to_vec();
            let plen = tokens.len();
            let mut logprobs = vec![0.0f32; plen];
            let mut mask = vec![0.0f32; plen];
            let finished = resp.last() == Some(&EOS);
            for &t in &resp {
                tokens.push(t);
                logprobs.push(-1.0 - rng.uniform() as f32);
                mask.push(1.0);
            }
            outs.push(GenOutput {
                tokens,
                prompt_len: plen,
                logprobs,
                loss_mask: mask,
                finished,
                version: self.weight_version(),
            });
        }
        Ok(outs)
    }

    fn weight_version(&self) -> u64 {
        self.version.load(std::sync::atomic::Ordering::SeqCst)
    }
}

impl RolloutEndpoint for MockModel {
    /// Version-only sync: the mock has no real weights, but tracking the
    /// published version lets service/scheduler tests observe rolling
    /// updates across replicas.
    fn sync_weights(&self, sync: &dyn WeightSync) -> Result<bool> {
        let latest = sync.latest_version();
        if latest > self.weight_version() {
            self.set_version(latest);
            return Ok(true);
        }
        Ok(false)
    }

    fn set_weights(&self, _snapshot: &WeightSnapshot, version: u64) -> Result<()> {
        self.set_version(version);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_model_shapes() {
        let m = MockModel::new(1, std::time::Duration::ZERO, 0.0);
        let outs = m.chat(&[1, 10, 11], 3, &SamplingArgs::default()).unwrap();
        assert_eq!(outs.len(), 3);
        for o in outs {
            assert_eq!(o.prompt_len, 3);
            assert_eq!(o.tokens.len(), o.logprobs.len());
            assert_eq!(o.tokens.len(), o.loss_mask.len());
            assert!(o.finished);
            assert_eq!(o.loss_mask[..3], [0.0, 0.0, 0.0]);
            assert!(o.loss_mask[3..].iter().all(|&m| m == 1.0));
        }
    }

    #[test]
    fn mock_model_latency_is_settable() {
        let m = MockModel::new(1, std::time::Duration::from_millis(5), 0.0);
        assert_eq!(m.latency(), std::time::Duration::from_millis(5));
        let t0 = std::time::Instant::now();
        m.chat(&[1], 1, &SamplingArgs::default()).unwrap();
        assert!(t0.elapsed() >= std::time::Duration::from_millis(5));
        m.set_latency(std::time::Duration::ZERO);
        assert_eq!(m.latency(), std::time::Duration::ZERO);
    }

    #[test]
    fn mock_model_failure_injection() {
        let m = MockModel::new(2, std::time::Duration::ZERO, 1.0);
        assert!(m.chat(&[1], 1, &SamplingArgs::default()).is_err());
    }

    #[test]
    fn log_softmax_at_matches_manual() {
        let logits = [1.0f32, 2.0, 3.0];
        let lp = log_softmax_at(&logits, 2);
        let z: f32 = logits.iter().map(|x| x.exp()).sum();
        assert!((lp - (3.0f32.exp() / z).ln()).abs() < 1e-6);
    }
}
