//! The explorer — rollout side of the trinity (paper §2.1, Fig. 3).
//!
//! * [`generation`] — the vLLM stand-in: KV-cache prefill/decode sessions,
//!   batched sampling, multi-turn continuation without re-prefill.
//! * [`workflow`] — the `Workflow` / `MultiTurnWorkflow` abstraction and
//!   registry, with the paper's built-ins (math, ALFWorld, reflect-once
//!   experience synthesis).
//! * [`runner`] — workflow runners: streaming completion, per-task
//!   timeout, bounded retry, skip-on-failure (paper §2.2).
//! * [`explorer`] — the Explorer actor: task intake, buffer emission,
//!   weight-sync participation, bench-mode evaluation.

pub mod explorer;
pub mod generation;
pub mod runner;
pub mod workflow;

pub use explorer::{EvalReport, Explorer, ExplorerConfig};
pub use generation::{
    GenOutput, GenerationEngine, MockModel, RolloutEndpoint, RolloutModel, SamplingArgs, Session,
};
pub use runner::{RunnerConfig, RunnerStats, WorkflowRunner};
pub use workflow::{
    AlfworldWorkflow, MathWorkflow, ReflectOnceWorkflow, Task, Workflow, WorkflowCtx,
    WorkflowRegistry,
};
