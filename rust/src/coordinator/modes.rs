//! The unified RFT modes (paper §2.1.1, Fig. 4): synchronous (any
//! `sync_interval`), one-step off-policy (`sync_offset >= 1`), fully
//! asynchronous, multi-explorer asynchronous, bench, and train-only —
//! all over the same explorer / buffer / trainer trinity, differing only
//! in coordination.
//!
//! Coordination model for `mode=both` (sync / one-step off-policy): the
//! explorer may start rollout batch `e` once the weight-sync window
//! `floor((e - sync_offset) / sync_interval)` has been published by the
//! trainer; the trainer trains whenever the buffer has a batch and
//! publishes weights every `sync_interval` steps.  With interval=1 and
//! offset=0 this degenerates to the strictly on-policy ping-pong with its
//! pipeline bubbles; larger intervals/offsets open the pipeline exactly as
//! in Fig. 4 (a)/(b).  `mode=async` drops the gating entirely: explorers
//! free-run against the buffer's backpressure and pull weights whenever
//! the trainer publishes (Fig. 4 (c)/(d)).

use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::buffer::{ExperienceBuffer, QueueBuffer, StrategyCtx};
use crate::data::ShapingBuffer;
use crate::exec::CancellationToken;
use crate::explorer::{
    EvalReport, Explorer, ExplorerConfig, GenerationEngine, RunnerConfig, SamplingArgs,
    WorkflowRegistry,
};
use crate::model::{CheckpointSync, MemorySync, ParamStore, WeightSync};
use crate::runtime::{Manifest, ModelEngine, RuntimeClient};
use crate::tokenizer::Tokenizer;
use crate::trainer::{AlgorithmRegistry, StepMetrics, Trainer, TrainerConfig};

use super::config::RftConfig;
use super::monitor::Monitor;
use super::tasks::{AlfworldTaskSource, MathTaskSource, TaskSource};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RftMode {
    /// Synchronous / one-step off-policy (explorer+trainer coordinated).
    Both,
    /// Fully asynchronous (incl. multi-explorer).
    Async,
    /// Trainer alone on an existing buffer (SFT/DPO/offline RL).
    TrainOnly,
    /// Evaluation of current/checkpointed weights.
    Bench,
}

impl RftMode {
    /// Case-insensitive mode lookup.
    pub fn parse(s: &str) -> Result<RftMode> {
        Ok(match s.trim().to_ascii_lowercase().as_str() {
            "both" => RftMode::Both,
            "async" | "explore" => RftMode::Async,
            "train" => RftMode::TrainOnly,
            "bench" => RftMode::Bench,
            _ => bail!("unknown mode '{s}' (valid modes: both, async, explore, train, bench)"),
        })
    }
}

/// One span on the Fig.-4-style timeline.
#[derive(Debug, Clone)]
pub struct TimelineEvent {
    pub role: String,
    pub kind: String,
    pub index: u64,
    pub start_s: f64,
    pub end_s: f64,
}

#[derive(Debug, Default)]
pub struct ModeReport {
    pub mode: String,
    pub wall_s: f64,
    pub train_steps: u64,
    pub explore_batches: u64,
    pub sync_count: u64,
    /// Explorer worker-pool busy fraction, percent (GPU-util analog).
    pub explorer_util: f64,
    /// Trainer compute fraction of wall time, percent.
    pub trainer_util: f64,
    /// Combined PJRT busy fraction, percent (GPU-power analog).
    pub device_busy: f64,
    pub trainer_metrics: Vec<StepMetrics>,
    pub timeline: Vec<TimelineEvent>,
    /// (step, weights) snapshots taken every `eval_every` steps.
    pub snapshots: Vec<(u64, Vec<Vec<f32>>)>,
    pub final_eval: Option<EvalReport>,
}

impl ModeReport {
    pub fn series(&self, metric: &str) -> Vec<f64> {
        self.trainer_metrics
            .iter()
            .filter_map(|m| m.get(metric).map(|v| v as f64))
            .collect()
    }
    pub fn reward_series(&self) -> Vec<f64> {
        self.trainer_metrics.iter().map(|m| m.mean_reward).collect()
    }
    pub fn response_len_series(&self) -> Vec<f64> {
        self.trainer_metrics.iter().map(|m| m.mean_response_len).collect()
    }
}

struct CoordState {
    synced_windows: u64,
    explored_batches: u64,
    failed: bool,
}

/// A fully wired RFT run (the launcher).
pub struct RftSession {
    pub cfg: RftConfig,
    pub monitor: Arc<Monitor>,
    pub tokenizer: Arc<Tokenizer>,
    pub manifest: Arc<Manifest>,
    pub client: Arc<RuntimeClient>,
    pub engine: Arc<ModelEngine>,
    pub buffer: Arc<dyn ExperienceBuffer>,
    pub sync: Arc<dyn WeightSync>,
    pub explorers: Vec<Arc<Explorer>>,
    pub task_source: Arc<dyn TaskSource>,
    pub trainer: Option<Trainer>,
    origin: Instant,
    timeline: Arc<Mutex<Vec<TimelineEvent>>>,
}

/// Optional overrides for [`RftSession::build_with`]: data pipelines and
/// custom-algorithm resources plug in here.
#[derive(Default)]
pub struct BuildOpts {
    pub task_source: Option<Arc<dyn TaskSource>>,
    pub processor: Option<Arc<dyn crate::data::ExperienceProcessor>>,
    /// Expert-trajectory buffer for algorithms whose sample strategy
    /// mixes a second source (MIX-family specs).
    pub expert_buffer: Option<Arc<dyn ExperienceBuffer>>,
}

impl RftSession {
    /// Wire up a session from config.  `task_source` / `processor`
    /// override the defaults (data pipelines plug in here).
    pub fn build(
        cfg: RftConfig,
        task_source: Option<Arc<dyn TaskSource>>,
        processor: Option<Arc<dyn crate::data::ExperienceProcessor>>,
    ) -> Result<RftSession> {
        Self::build_with(cfg, BuildOpts { task_source, processor, expert_buffer: None })
    }

    /// Wire up a session from config with the full override set.
    pub fn build_with(cfg: RftConfig, opts: BuildOpts) -> Result<RftSession> {
        let BuildOpts { task_source, processor, expert_buffer } = opts;
        let manifest = Arc::new(match &cfg.artifacts_dir {
            Some(d) => Manifest::load(d)?,
            None => Manifest::load_default().context("artifacts not built (run `make artifacts`)")?,
        });
        let client = RuntimeClient::global();
        let engine = Arc::new(ModelEngine::new(client.clone(), &manifest, &cfg.model_preset)?);
        engine.validate_manifest()?;
        engine.warmup()?;
        let tokenizer = Arc::new(Tokenizer::new());
        let monitor = Arc::new(Monitor::new(cfg.monitor_dir.clone())?);

        // both sides start from identical weights
        let trainer_params = ParamStore::init(&engine.model, cfg.seed)?;
        let init_snapshot = trainer_params.snapshot()?;

        // buffer (+ optional experience shaping stage)
        let queue = Arc::new(QueueBuffer::new(cfg.buffer_capacity));
        let base: Arc<dyn ExperienceBuffer> = queue;
        let buffer: Arc<dyn ExperienceBuffer> = match processor {
            Some(p) => Arc::new(ShapingBuffer::new(base, p)),
            None => base,
        };

        // weight sync service
        let sync: Arc<dyn WeightSync> = match cfg.sync_method.as_str() {
            "memory" => Arc::new(MemorySync::new()),
            "checkpoint" => {
                let dir = cfg
                    .sync_dir
                    .clone()
                    .unwrap_or_else(|| std::env::temp_dir().join("trft_sync"));
                let names = engine
                    .model
                    .params
                    .iter()
                    .map(|p| (p.name.clone(), p.shape.clone()))
                    .collect();
                Arc::new(CheckpointSync::new(dir, &cfg.model_preset, names)?)
            }
            other => bail!("unknown sync method '{other}'"),
        };

        // explorers
        let registry = Arc::new(WorkflowRegistry::with_builtins());
        let sampling = SamplingArgs {
            temperature: cfg.temperature,
            top_k: cfg.top_k,
            top_p: cfg.top_p,
            max_new_tokens: cfg.max_new_tokens,
            seed: cfg.seed,
        };
        let mut explorers = Vec::with_capacity(cfg.explorer_count);
        for i in 0..cfg.explorer_count {
            let params = ParamStore::from_snapshot(&engine.model, &init_snapshot)?;
            let gen = Arc::new(GenerationEngine::new(Arc::clone(&engine), params));
            let ex_cfg = ExplorerConfig {
                runner: RunnerConfig {
                    timeout: Duration::from_secs_f64(cfg.task_timeout_s),
                    max_attempts: cfg.task_max_attempts,
                    retry_delay: Duration::from_millis(20),
                    seed: cfg.seed ^ (i as u64) << 8,
                },
                sampling: sampling.clone(),
                threads: cfg.explorer_threads,
            };
            explorers.push(Arc::new(Explorer::new(
                i,
                gen,
                Arc::clone(&registry),
                Arc::clone(&tokenizer),
                Arc::clone(&buffer),
                ex_cfg,
            )));
        }

        // task source
        let task_source: Arc<dyn TaskSource> = match task_source {
            Some(s) => s,
            None => match cfg.workflow.as_str() {
                "alfworld" => Arc::new(AlfworldTaskSource::new(cfg.seed, cfg.repeat_times)),
                _ => Arc::new(MathTaskSource::new(
                    cfg.seed,
                    cfg.min_difficulty,
                    cfg.max_difficulty,
                    cfg.repeat_times,
                )),
            },
        };

        // trainer: resolve the algorithm spec from the registry; the
        // spec links its own sample strategy (paper §3.2)
        let spec = AlgorithmRegistry::global().get(&cfg.algorithm)?;
        let mut tcfg = TrainerConfig::from_spec(Arc::clone(&spec));
        tcfg.algorithm.hyper = cfg.effective_hyper(&spec);
        tcfg.algorithm.adv_std_normalize = cfg.adv_std_normalize;
        let strategy = spec.sample.build(&StrategyCtx {
            buffer: Arc::clone(&buffer),
            expert_buffer,
            expert_fraction: cfg.mix.expert_fraction,
            timeout: Duration::from_secs(600),
        })?;
        let trainer = Trainer::new(Arc::clone(&engine), trainer_params, strategy, tcfg)?;

        Ok(RftSession {
            cfg,
            monitor,
            tokenizer,
            manifest,
            client,
            engine,
            buffer,
            sync,
            explorers,
            task_source,
            trainer: Some(trainer),
            origin: Instant::now(),
            timeline: Arc::new(Mutex::new(vec![])),
        })
    }

    fn record(&self, role: &str, kind: &str, index: u64, start: Instant, end: Instant) {
        let origin = self.origin;
        self.timeline.lock().unwrap().push(TimelineEvent {
            role: role.to_string(),
            kind: kind.to_string(),
            index,
            start_s: start.duration_since(origin).as_secs_f64(),
            end_s: end.duration_since(origin).as_secs_f64(),
        });
    }

    /// Dispatch on the configured mode.
    pub fn run(&mut self) -> Result<ModeReport> {
        match RftMode::parse(&self.cfg.mode)? {
            RftMode::Both => self.run_both(),
            RftMode::Async => self.run_async(),
            RftMode::TrainOnly => self.run_train_only(),
            RftMode::Bench => bail!("use run_bench(tiers) for bench mode"),
        }
    }

    /// Synchronous family (Fig. 4 a/b): windowed gating between explorer
    /// and trainer.
    pub fn run_both(&mut self) -> Result<ModeReport> {
        let cfg = self.cfg.clone();
        let total = cfg.total_steps;
        let interval = cfg.sync_interval;
        let offset = cfg.sync_offset;
        let mut trainer = self.trainer.take().context("trainer already consumed")?;
        let explorer = Arc::clone(&self.explorers[0]);
        let source = Arc::clone(&self.task_source);
        let sync = Arc::clone(&self.sync);
        let monitor = Arc::clone(&self.monitor);
        let coord = Arc::new((
            Mutex::new(CoordState { synced_windows: 0, explored_batches: 0, failed: false }),
            Condvar::new(),
        ));

        explorer.reset_utilization();
        let run_start = Instant::now();
        let origin = self.origin;
        let timeline = Arc::clone(&self.timeline);

        // ---- explorer thread ----
        let exp_coord = Arc::clone(&coord);
        let exp_monitor = Arc::clone(&monitor);
        let exp_timeline = Arc::clone(&timeline);
        let explorer_handle = std::thread::Builder::new()
            .name("explorer-loop".into())
            .spawn(move || -> Result<()> {
                for e in 0..total {
                    let need_window = e.saturating_sub(offset) / interval;
                    {
                        let (lock, cvar) = &*exp_coord;
                        let mut st = lock.lock().unwrap();
                        while st.synced_windows < need_window && !st.failed {
                            st = cvar.wait(st).unwrap();
                        }
                        if st.failed {
                            return Ok(());
                        }
                    }
                    explorer.sync_weights(&*sync)?;
                    let t0 = Instant::now();
                    let tasks = source.next_batch(cfg.batch_tasks);
                    let stats = explorer.explore_batch(tasks)?;
                    let t1 = Instant::now();
                    exp_timeline.lock().unwrap().push(TimelineEvent {
                        role: "explorer".into(),
                        kind: "rollout".into(),
                        index: e,
                        start_s: t0.duration_since(origin).as_secs_f64(),
                        end_s: t1.duration_since(origin).as_secs_f64(),
                    });
                    exp_monitor.log(
                        "explorer",
                        e,
                        &[
                            ("experiences".into(), stats.experiences as f64),
                            ("skipped".into(), stats.skipped as f64),
                            ("batch_s".into(), (t1 - t0).as_secs_f64()),
                        ],
                    );
                    let (lock, cvar) = &*exp_coord;
                    lock.lock().unwrap().explored_batches += 1;
                    cvar.notify_all();
                }
                Ok(())
            })
            .expect("spawn explorer loop");

        // ---- trainer loop (this thread) ----
        let mut compute_total = 0.0;
        let mut sync_count = 0u64;
        let mut snapshots = vec![];
        let mut train_err: Option<anyhow::Error> = None;
        for t in 0..total {
            let t0 = Instant::now();
            let m = match trainer.train_step() {
                Ok(m) => m,
                Err(e) => {
                    train_err = Some(e);
                    let (lock, cvar) = &*coord;
                    lock.lock().unwrap().failed = true;
                    cvar.notify_all();
                    break;
                }
            };
            let t1 = Instant::now();
            compute_total += m.compute_s;
            self.record("trainer", "train", t, t0, t1);
            let mut logs: Vec<(String, f64)> = vec![
                ("reward".into(), m.mean_reward),
                ("response_len".into(), m.mean_response_len),
                ("sample_wait_s".into(), m.sample_wait_s),
                ("compute_s".into(), m.compute_s),
            ];
            logs.extend(m.named.iter().map(|(n, v)| (n.clone(), *v as f64)));
            monitor.log("trainer", m.step, &logs);

            if (t + 1) % interval == 0 {
                let s0 = Instant::now();
                trainer.publish_weights(self.sync.as_ref())?;
                sync_count += 1;
                self.record("trainer", "weight_sync", sync_count, s0, Instant::now());
                let (lock, cvar) = &*coord;
                lock.lock().unwrap().synced_windows += 1;
                cvar.notify_all();
            }
            if cfg.eval_every > 0 && (t + 1) % cfg.eval_every == 0 {
                snapshots.push((t + 1, trainer.params().snapshot()?));
            }
        }

        let explorer_result = explorer_handle.join().expect("explorer thread");
        if let Some(e) = train_err {
            return Err(e.context("trainer loop failed"));
        }
        explorer_result.context("explorer loop failed")?;

        let wall = run_start.elapsed().as_secs_f64();
        let report = ModeReport {
            mode: format!("both(i={interval},o={offset})"),
            wall_s: wall,
            train_steps: trainer.step(),
            explore_batches: coord.0.lock().unwrap().explored_batches,
            sync_count,
            explorer_util: self.explorers[0].utilization_percent(),
            trainer_util: 100.0 * compute_total / wall,
            device_busy: 100.0 * self.client.total_exec_seconds().min(wall) / wall,
            trainer_metrics: trainer.history().to_vec(),
            timeline: self.timeline.lock().unwrap().clone(),
            snapshots,
            final_eval: None,
        };
        self.trainer = Some(trainer);
        Ok(report)
    }

    /// Fully asynchronous (Fig. 4 c) and multi-explorer (Fig. 4 d):
    /// explorers free-run against buffer backpressure; the trainer
    /// publishes weights every `sync_interval` steps and explorers pull at
    /// their own pace.
    pub fn run_async(&mut self) -> Result<ModeReport> {
        let cfg = self.cfg.clone();
        let total = cfg.total_steps;
        let interval = cfg.sync_interval;
        let mut trainer = self.trainer.take().context("trainer already consumed")?;
        let monitor = Arc::clone(&self.monitor);
        let cancel = CancellationToken::new();
        let origin = self.origin;
        let timeline = Arc::clone(&self.timeline);

        let run_start = Instant::now();
        let mut handles = vec![];
        for explorer in &self.explorers {
            explorer.reset_utilization();
            let explorer = Arc::clone(explorer);
            let source = Arc::clone(&self.task_source);
            let sync = Arc::clone(&self.sync);
            let cancel = cancel.clone();
            let monitor = Arc::clone(&monitor);
            let timeline = Arc::clone(&timeline);
            let batch_tasks = cfg.batch_tasks;
            handles.push(
                std::thread::Builder::new()
                    .name(format!("explorer-{}", explorer.id))
                    .spawn(move || -> Result<u64> {
                        let mut batches = 0u64;
                        while !cancel.is_cancelled() {
                            // staggered weight pulls: explorers sync whenever
                            // something newer exists (their own pace)
                            let _ = explorer.sync_weights(&*sync);
                            let t0 = Instant::now();
                            let tasks = source.next_batch(batch_tasks);
                            match explorer.explore_batch(tasks) {
                                Ok(stats) => {
                                    let t1 = Instant::now();
                                    timeline.lock().unwrap().push(TimelineEvent {
                                        role: format!("explorer-{}", explorer.id),
                                        kind: "rollout".into(),
                                        index: batches,
                                        start_s: t0.duration_since(origin).as_secs_f64(),
                                        end_s: t1.duration_since(origin).as_secs_f64(),
                                    });
                                    monitor.log(
                                        &format!("explorer-{}", explorer.id),
                                        batches,
                                        &[
                                            ("experiences".into(), stats.experiences as f64),
                                            ("weight_version".into(), explorer.weight_version() as f64),
                                        ],
                                    );
                                    batches += 1;
                                }
                                Err(e) => {
                                    if cancel.is_cancelled() {
                                        break; // buffer closed at shutdown
                                    }
                                    crate::log_warn!("explorer", "batch failed: {e:#}");
                                }
                            }
                        }
                        Ok(batches)
                    })
                    .expect("spawn explorer"),
            );
        }

        // trainer free-runs on this thread
        let mut compute_total = 0.0;
        let mut sync_count = 0u64;
        let mut snapshots = vec![];
        let mut result: Result<()> = Ok(());
        for t in 0..total {
            let t0 = Instant::now();
            match trainer.train_step() {
                Ok(m) => {
                    compute_total += m.compute_s;
                    self.record("trainer", "train", t, t0, Instant::now());
                    let mut logs: Vec<(String, f64)> = vec![
                        ("reward".into(), m.mean_reward),
                        ("response_len".into(), m.mean_response_len),
                        ("sample_wait_s".into(), m.sample_wait_s),
                    ];
                    logs.extend(m.named.iter().map(|(n, v)| (n.clone(), *v as f64)));
                    monitor.log("trainer", m.step, &logs);
                }
                Err(e) => {
                    result = Err(e.context("async trainer failed"));
                    break;
                }
            }
            if (t + 1) % interval == 0 {
                trainer.publish_weights(&*sync_ref(&self.sync))?;
                sync_count += 1;
            }
            if cfg.eval_every > 0 && (t + 1) % cfg.eval_every == 0 {
                snapshots.push((t + 1, trainer.params().snapshot()?));
            }
        }

        cancel.cancel();
        self.buffer.close();
        let mut explore_batches = 0;
        for h in handles {
            explore_batches += h.join().expect("explorer thread")?;
        }
        result?;

        let wall = run_start.elapsed().as_secs_f64();
        let report = ModeReport {
            mode: format!("async(i={interval},x{})", cfg.explorer_count),
            wall_s: wall,
            train_steps: trainer.step(),
            explore_batches,
            sync_count,
            explorer_util: self
                .explorers
                .iter()
                .map(|e| e.utilization_percent())
                .sum::<f64>()
                / self.explorers.len() as f64,
            trainer_util: 100.0 * compute_total / wall,
            device_busy: 100.0 * self.client.total_exec_seconds().min(wall) / wall,
            trainer_metrics: trainer.history().to_vec(),
            timeline: self.timeline.lock().unwrap().clone(),
            snapshots,
            final_eval: None,
        };
        self.trainer = Some(trainer);
        Ok(report)
    }

    /// Train-only mode (paper §2.1.1): offline SFT/DPO/off-policy RL on a
    /// pre-filled buffer; no explorers launched.
    pub fn run_train_only(&mut self) -> Result<ModeReport> {
        let cfg = self.cfg.clone();
        let mut trainer = self.trainer.take().context("trainer already consumed")?;
        let monitor = Arc::clone(&self.monitor);
        let run_start = Instant::now();
        let mut compute_total = 0.0;
        let mut snapshots = vec![];
        for t in 0..cfg.total_steps {
            let m = trainer.train_step().context("train-only step")?;
            compute_total += m.compute_s;
            let mut logs: Vec<(String, f64)> =
                vec![("reward".into(), m.mean_reward), ("compute_s".into(), m.compute_s)];
            logs.extend(m.named.iter().map(|(n, v)| (n.clone(), *v as f64)));
            monitor.log("trainer", m.step, &logs);
            if cfg.eval_every > 0 && (t + 1) % cfg.eval_every == 0 {
                snapshots.push((t + 1, trainer.params().snapshot()?));
            }
        }
        let wall = run_start.elapsed().as_secs_f64();
        let report = ModeReport {
            mode: "train".into(),
            wall_s: wall,
            train_steps: trainer.step(),
            trainer_util: 100.0 * compute_total / wall,
            device_busy: 100.0 * self.client.total_exec_seconds().min(wall) / wall,
            trainer_metrics: trainer.history().to_vec(),
            snapshots,
            ..Default::default()
        };
        self.trainer = Some(trainer);
        Ok(report)
    }

    /// Bench mode: evaluate the explorer's current weights (or a loaded
    /// snapshot) on benchmark tiers; Avg@K per tier.
    pub fn run_bench(
        &self,
        tiers: &[&str],
        tasks_per_tier: usize,
        repeat_times: usize,
        temperature: f32,
    ) -> Result<Vec<(String, EvalReport)>> {
        let explorer = &self.explorers[0];
        let mut out = Vec::with_capacity(tiers.len());
        for tier in tiers {
            let tasks =
                super::tasks::benchmark_tasks(tier, tasks_per_tier, repeat_times, self.cfg.seed ^ 0xbe);
            let report = explorer.evaluate(&tasks, temperature)?;
            out.push((tier.to_string(), report));
        }
        Ok(out)
    }

    /// Load a weight snapshot into every explorer (bench over checkpoints).
    pub fn load_explorer_weights(&self, weights: &[Vec<f32>], version: u64) -> Result<()> {
        for e in &self.explorers {
            e.engine().set_weights(weights, version)?;
        }
        Ok(())
    }
}

fn sync_ref(s: &Arc<dyn WeightSync>) -> &dyn WeightSync {
    s.as_ref()
}

/// Convenience entry point: build + run from a config.
pub fn run_mode(cfg: RftConfig) -> Result<ModeReport> {
    let mut session = RftSession::build(cfg, None, None)?;
    session.run()
}

/// SFT warm-up producing a weight snapshot (the paper's
/// `sft_warmup_dataset` pattern): a cold random model emits no valid
/// answers, so GRPO's group rewards are all zero and carry no gradient;
/// a short supervised phase on gold answers breaks the degeneracy.
/// Learning benches and the e2e example start from this snapshot.
pub fn sft_warmup_snapshot(preset: &str, seed: u64, steps: u64) -> Result<Vec<Vec<f32>>> {
    use crate::data::formatter::{FormatSpec, Formatter};
    use crate::envs::math::MathTaskGen;
    use crate::util::json::Value;

    let mut cfg = RftConfig::default();
    cfg.mode = "train".into();
    cfg.algorithm = "sft".into();
    cfg.model_preset = preset.into();
    cfg.total_steps = steps;
    cfg.seed = seed;
    cfg.hyper.lr = 2e-3;
    let mut session = RftSession::build(cfg, None, None)?;
    let formatter =
        Formatter { spec: FormatSpec::default(), tokenizer: Arc::clone(&session.tokenizer) };
    let (b, _, _) = session.engine.train_shape("sft")?;
    let mut gen = MathTaskGen::new(seed ^ 0x5f7, "warmup");
    let mut exps = Vec::with_capacity(steps as usize * b);
    for _ in 0..(steps as usize * b) {
        let t = gen.gen(1);
        let raw = Value::obj(vec![
            ("question", Value::str(t.question.clone())),
            ("answer", Value::str(t.answer.to_string())),
        ]);
        exps.push(formatter.to_expert_experience(&raw)?);
    }
    session.buffer.write(exps)?;
    session.run()?;
    session.trainer.as_ref().unwrap().params().snapshot()
}

impl RftSession {
    /// Start trainer AND all explorers from an externally produced weight
    /// snapshot (e.g. [`sft_warmup_snapshot`]).
    pub fn load_initial_weights(&mut self, weights: &[Vec<f32>]) -> Result<()> {
        self.trainer
            .as_mut()
            .context("trainer already consumed")?
            .load_weights(weights, 1, true)?;
        self.load_explorer_weights(weights, 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_parse_is_case_insensitive() {
        assert_eq!(RftMode::parse("both").unwrap(), RftMode::Both);
        assert_eq!(RftMode::parse("BOTH").unwrap(), RftMode::Both);
        assert_eq!(RftMode::parse(" Async ").unwrap(), RftMode::Async);
        assert_eq!(RftMode::parse("Explore").unwrap(), RftMode::Async);
        assert_eq!(RftMode::parse("TRAIN").unwrap(), RftMode::TrainOnly);
        assert_eq!(RftMode::parse("Bench").unwrap(), RftMode::Bench);
    }

    #[test]
    fn mode_parse_error_lists_valid_modes() {
        let err = RftMode::parse("warp").unwrap_err().to_string();
        assert!(err.contains("unknown mode 'warp'"), "{err}");
        for valid in ["both", "async", "explore", "train", "bench"] {
            assert!(err.contains(valid), "error should list '{valid}': {err}");
        }
    }
}
