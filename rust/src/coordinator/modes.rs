//! Back-compat shim: the seed's three hand-rolled mode loops were
//! unified into one scheduler (see [`scheduler`](super::scheduler)) with
//! pluggable [`policy`](super::policy) values; reporting moved to
//! [`report`](super::report).  This module only re-exports the moved
//! names so existing `coordinator::modes::` paths keep compiling.

pub use super::policy::RftMode;
pub use super::report::{ModeReport, TimelineEvent};
pub use super::scheduler::{run_mode, sft_warmup_snapshot, BuildOpts, RftSession};
