//! The unified RFT-core scheduler (paper §2.1.1, Fig. 4): ONE
//! coordination engine behind every mode.  The seed's three hand-rolled
//! loops (`run_both` / `run_async` / `run_train_only`) are gone — a
//! single generic trainer driver plus N generic explorer drivers run on
//! `exec` primitives (thread pool, watch cell, cancellation token), and
//! a [`SyncPolicy`] decides explorer admission, weight-publish cadence,
//! and shutdown shape.  `both` / `async` / `train` are just policy
//! values ([`Windowed`](super::policy::Windowed) /
//! [`Free`](super::policy::Free) / [`Offline`](super::policy::Offline)),
//! and [`BoundedStaleness`](super::policy::BoundedStaleness) adds the
//! off-policyness control as a first-class mode.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::buffer::{ExperienceBuffer, QueueBuffer, StrategyCtx};
use crate::control::{ControlContext, ControlPlane};
use crate::data::ShapingBuffer;
use crate::exec::{CancellationToken, Promise, ThreadPool, WatchCell};
use crate::explorer::{
    EvalReport, Explorer, ExplorerConfig, GenerationEngine, RolloutEndpoint, RunnerConfig,
    SamplingArgs, WorkflowRegistry,
};
use crate::model::{ParamStore, SyncCtx, WeightSnapshot, WeightSync, WeightSyncRegistry};
use crate::obs::{
    attribute, write_trace, Anomaly, FlightRecorder, Gauges, SloEngine, SpanRecorder, TelemetryHub,
};
use crate::runtime::{Manifest, ModelEngine, RuntimeClient};
use crate::service::RolloutService;
use crate::tokenizer::Tokenizer;
use crate::trainer::{AlgorithmRegistry, Trainer, TrainerConfig};

use super::config::RftConfig;
use super::monitor::Monitor;
use super::policy::{resolve_policy, ExplorerPlan, Progress, SyncPolicy};
use super::report::{FlightStats, ModeReport, RolloutRecord, RunRecorder};
use super::tasks::{AlfworldTaskSource, MathTaskSource, ShardedTaskSource, TaskSource};

/// Shared run state: the policy-visible [`Progress`] plus the failure
/// flag that releases blocked explorer drivers.
#[derive(Default)]
struct RunState {
    progress: Progress,
    failed: bool,
}

/// Everything one explorer driver needs; the driver itself is the single
/// generic explorer loop (there are no per-mode copies).
struct ExplorerDriver {
    explorer: Arc<Explorer>,
    source: Arc<dyn TaskSource>,
    sync: Arc<dyn WeightSync>,
    policy: Arc<dyn SyncPolicy>,
    recorder: Arc<RunRecorder>,
    state: Arc<WatchCell<RunState>>,
    cancel: CancellationToken,
    batch_tasks: usize,
    /// Control plane when `[control]` is enabled: the admission gate
    /// joins the policy's `admit`, and per-batch task counts come from
    /// the capacity controller instead of the static `batch_tasks`.
    control: Option<Arc<ControlPlane>>,
    plan: ExplorerPlan,
    role: String,
}

impl ExplorerDriver {
    /// The generic explorer loop: admission-gate, pull weights, roll out
    /// one batch, record, repeat.  With a fixed batch budget errors are
    /// fatal (lockstep modes); free-running drivers warn and continue,
    /// and exit when the trainer cancels the run.
    fn run(self) -> Result<u64> {
        let budget = match self.plan {
            ExplorerPlan::None => return Ok(0),
            ExplorerPlan::Batches(n) => Some(n),
            ExplorerPlan::FreeRun => None,
        };
        let mut batches = 0u64;
        loop {
            if let Some(limit) = budget {
                if batches >= limit {
                    break;
                }
            }
            // block until the policy admits this batch (or the run
            // ends); free-running drivers additionally hold while the
            // control plane's admission gate reports over-band serving
            // pressure (budgeted plans stay policy-only — their last
            // batches may outlive the trainer's gauge feed)
            let admitted = self.state.wait_until(|st| {
                if self.cancel.is_cancelled() || st.failed {
                    return Some(false);
                }
                if !self.policy.admit(batches, st.progress) {
                    return None;
                }
                if budget.is_none() {
                    if let Some(plane) = &self.control {
                        if !plane.admit() {
                            return None;
                        }
                    }
                }
                Some(true)
            });
            if !admitted {
                break;
            }
            if let Err(e) = self.explorer.sync_weights(&*self.sync) {
                if self.cancel.is_cancelled() {
                    break;
                }
                if budget.is_some() {
                    return Err(e.context("weight pull failed"));
                }
                crate::log_warn!("scheduler", "{}: weight pull failed: {e:#}", self.role);
            }
            let version = self.explorer.weight_version();
            let lag = self.policy.version_lag(batches, version);
            let t0 = Instant::now();
            let batch_tasks = match &self.control {
                Some(plane) => plane.batch_tasks(),
                None => self.batch_tasks,
            };
            let tasks = self.source.next_batch(batch_tasks);
            match self.explorer.explore_batch(tasks) {
                Ok(stats) => {
                    let rec = RolloutRecord {
                        role: &self.role,
                        batch: batches,
                        stats: &stats,
                        weight_version: version,
                        version_lag: lag,
                    };
                    self.recorder.rollout(&rec, t0, Instant::now());
                    batches += 1;
                    let depth = self.explorer.buffer_depth() as u64;
                    self.state.update(|st| {
                        st.progress.explored_batches += 1;
                        st.progress.buffer_depth = depth;
                    });
                }
                Err(e) => {
                    if self.cancel.is_cancelled() {
                        break; // buffer closed at shutdown
                    }
                    if budget.is_some() {
                        return Err(e);
                    }
                    crate::log_warn!("scheduler", "{}: batch failed: {e:#}", self.role);
                }
            }
        }
        Ok(batches)
    }
}

/// A fully wired RFT run (the launcher).
pub struct RftSession {
    pub cfg: RftConfig,
    pub monitor: Arc<Monitor>,
    pub tokenizer: Arc<Tokenizer>,
    pub manifest: Arc<Manifest>,
    pub client: Arc<RuntimeClient>,
    pub engine: Arc<ModelEngine>,
    pub buffer: Arc<dyn ExperienceBuffer>,
    pub sync: Arc<dyn WeightSync>,
    pub explorers: Vec<Arc<Explorer>>,
    /// The shared rollout service when `service.enabled` — explorers
    /// then hold service handles instead of direct engine handles.
    pub service: Option<Arc<RolloutService>>,
    pub task_source: Arc<dyn TaskSource>,
    pub trainer: Option<Trainer>,
    /// Per-episode span sink when `observability.enabled` — threaded
    /// into the service, replicas, engine, and run recorder; drained
    /// into a Chrome trace-event file at the end of each run.
    pub observer: Option<Arc<SpanRecorder>>,
    /// Live gauge hub when `observability.enabled` — the scheduler
    /// publishes samples on the configured cadence and policies read
    /// them via [`SyncPolicy::connect_telemetry`].
    pub telemetry: Option<Arc<TelemetryHub>>,
    /// Flight recorder when `observability.enabled` — anomaly triggers
    /// (breaker opens, deadline bursts, migration failures, SLO burn)
    /// dump self-contained diagnostic bundles into the monitor dir.
    pub flight: Option<Arc<FlightRecorder>>,
    /// Per-class SLO accountant when any class has a latency target —
    /// assessed on the gauge cadence, published as `slo_burn_*` gauges.
    pub slo: Option<Arc<SloEngine>>,
    origin: Instant,
}

/// Optional overrides for [`RftSession::build_with`]: data pipelines and
/// custom-algorithm resources plug in here.
#[derive(Default)]
pub struct BuildOpts {
    pub task_source: Option<Arc<dyn TaskSource>>,
    pub processor: Option<Arc<dyn crate::data::ExperienceProcessor>>,
    /// Expert-trajectory buffer for algorithms whose sample strategy
    /// mixes a second source (MIX-family specs).
    pub expert_buffer: Option<Arc<dyn ExperienceBuffer>>,
}

impl RftSession {
    /// Wire up a session from config.  `task_source` / `processor`
    /// override the defaults (data pipelines plug in here).
    pub fn build(
        cfg: RftConfig,
        task_source: Option<Arc<dyn TaskSource>>,
        processor: Option<Arc<dyn crate::data::ExperienceProcessor>>,
    ) -> Result<RftSession> {
        Self::build_with(cfg, BuildOpts { task_source, processor, expert_buffer: None })
    }

    /// Wire up a session from config with the full override set.
    pub fn build_with(cfg: RftConfig, opts: BuildOpts) -> Result<RftSession> {
        let BuildOpts { task_source, processor, expert_buffer } = opts;
        let manifest = Arc::new(match &cfg.artifacts_dir {
            Some(d) => Manifest::load(d)?,
            None => Manifest::load_default().context("artifacts not built (run `make artifacts`)")?,
        });
        let client = RuntimeClient::global();
        let engine = Arc::new(ModelEngine::new(client.clone(), &manifest, &cfg.model_preset)?);
        engine.validate_manifest()?;
        engine.warmup()?;
        let tokenizer = Arc::new(Tokenizer::new());
        let monitor = Arc::new(Monitor::new(cfg.monitor_dir.clone())?);

        // observability plane (DESIGN.md §8): one span recorder + one
        // gauge hub per session when enabled, nothing at all otherwise.
        // The control plane (DESIGN.md §9) feeds off the same gauge hub,
        // so `[control]` alone also brings the hub up (without spans).
        let obs_cfg = cfg.observability.to_obs_config();
        let observer = obs_cfg.enabled.then(|| Arc::new(SpanRecorder::new(obs_cfg.ring_capacity)));
        let telemetry = (obs_cfg.enabled || cfg.control.enabled)
            .then(|| Arc::new(TelemetryHub::with_history(obs_cfg.sample_every, obs_cfg.gauge_history)));
        if let Some(spans) = &observer {
            engine.set_observer(Arc::clone(spans));
        }

        // flight recorder (DESIGN.md §12): anomaly-triggered diagnostic
        // dumps, landing next to the monitor series unless a dump dir
        // is set explicitly
        let flight = obs_cfg.enabled.then(|| {
            let mut fcfg = obs_cfg.flight.clone();
            if fcfg.dir.is_none() {
                fcfg.dir = cfg.monitor_dir.clone();
            }
            let recorder = Arc::new(FlightRecorder::new(fcfg));
            recorder.set_config_digest(cfg.digest());
            if let Some(spans) = &observer {
                recorder.connect_spans(Arc::clone(spans));
            }
            if let Some(hub) = &telemetry {
                recorder.connect_hub(Arc::clone(hub));
            }
            recorder
        });
        // SLO engine: only when a class actually has a latency target —
        // burn assessment otherwise never pays the per-publish diff
        let slo = (obs_cfg.enabled && obs_cfg.slo.any_target())
            .then(|| Arc::new(SloEngine::new(obs_cfg.slo)));

        // both sides start from identical weights
        let trainer_params = ParamStore::init(&engine.model, cfg.seed)?;
        let init_snapshot = trainer_params.snapshot()?;

        // buffer (+ optional experience shaping stage)
        let queue = Arc::new(QueueBuffer::new(cfg.buffer_capacity));
        let base: Arc<dyn ExperienceBuffer> = queue;
        let buffer: Arc<dyn ExperienceBuffer> = match processor {
            Some(p) => Arc::new(ShapingBuffer::new(base, p)),
            None => base,
        };

        // weight sync service: `sync.method` resolves through the
        // factory registry (case-insensitive, catalog on error)
        let sync = WeightSyncRegistry::global().build(
            &cfg.sync_method,
            &SyncCtx {
                dir: cfg.sync_dir.clone(),
                preset: cfg.model_preset.clone(),
                leaf_names: engine
                    .model
                    .params
                    .iter()
                    .map(|p| (p.name.clone(), p.shape.clone()))
                    .collect(),
            },
        )?;

        // explorers
        let registry = Arc::new(WorkflowRegistry::with_builtins());
        let sampling = SamplingArgs {
            temperature: cfg.temperature,
            top_k: cfg.top_k,
            top_p: cfg.top_p,
            max_new_tokens: cfg.max_new_tokens,
            seed: cfg.seed,
            session: None,
            trace: 0,
            class: crate::qos::RequestClass::TrainRollout,
        };
        let ex_cfg = |i: usize| ExplorerConfig {
            runner: RunnerConfig {
                timeout: Duration::from_secs_f64(cfg.task_timeout_s),
                max_attempts: cfg.task_max_attempts,
                retry_delay: Duration::from_millis(20),
                seed: cfg.seed ^ (i as u64) << 8,
            },
            sampling: sampling.clone(),
            threads: cfg.explorer_threads,
        };
        let mut explorers = Vec::with_capacity(cfg.explorer_count);
        let mut service = None;
        if cfg.service.enabled {
            // the rollout service tier (paper §2.2): a replica pool of
            // engines shared by every explorer; each replica owns its
            // own ParamStore so weight publishes roll one replica at a
            // time without stopping traffic
            let mut engines = Vec::with_capacity(cfg.service.replicas);
            for _ in 0..cfg.service.replicas {
                let params = ParamStore::from_snapshot(&engine.model, &init_snapshot)?;
                engines.push(Arc::new(GenerationEngine::new(Arc::clone(&engine), params)));
            }
            let mut svc_cfg = cfg.service.to_service_config();
            svc_cfg.qos = cfg.qos.to_qos_config();
            let svc = Arc::new(RolloutService::over_engines_diag(
                engines,
                svc_cfg,
                observer.clone(),
                flight.clone(),
            )?);
            for i in 0..cfg.explorer_count {
                explorers.push(Arc::new(Explorer::with_endpoint(
                    i,
                    Arc::clone(&svc),
                    Arc::clone(&registry),
                    Arc::clone(&tokenizer),
                    Arc::clone(&buffer),
                    ex_cfg(i),
                )));
            }
            service = Some(svc);
        } else {
            for i in 0..cfg.explorer_count {
                let params = ParamStore::from_snapshot(&engine.model, &init_snapshot)?;
                let gen = Arc::new(GenerationEngine::new(Arc::clone(&engine), params));
                explorers.push(Arc::new(Explorer::new(
                    i,
                    gen,
                    Arc::clone(&registry),
                    Arc::clone(&tokenizer),
                    Arc::clone(&buffer),
                    ex_cfg(i),
                )));
            }
        }

        // task source
        let task_source: Arc<dyn TaskSource> = match task_source {
            Some(s) => s,
            None => match cfg.workflow.as_str() {
                "alfworld" => Arc::new(AlfworldTaskSource::new(cfg.seed, cfg.repeat_times)),
                _ => Arc::new(MathTaskSource::new(
                    cfg.seed,
                    cfg.min_difficulty,
                    cfg.max_difficulty,
                    cfg.repeat_times,
                )),
            },
        };

        // trainer: resolve the algorithm spec from the registry; the
        // spec links its own sample strategy (paper §3.2)
        let spec = AlgorithmRegistry::global().get(&cfg.algorithm)?;
        let mut tcfg = TrainerConfig::from_spec(Arc::clone(&spec));
        tcfg.algorithm.hyper = cfg.effective_hyper(&spec);
        tcfg.algorithm.adv_std_normalize = cfg.adv_std_normalize;
        let strategy = spec.sample.build(&StrategyCtx {
            buffer: Arc::clone(&buffer),
            expert_buffer,
            expert_fraction: cfg.mix.expert_fraction,
            timeout: Duration::from_secs(600),
        })?;
        let trainer = Trainer::new(Arc::clone(&engine), trainer_params, strategy, tcfg)?;

        Ok(RftSession {
            cfg,
            monitor,
            tokenizer,
            manifest,
            client,
            engine,
            buffer,
            sync,
            explorers,
            service,
            task_source,
            trainer: Some(trainer),
            observer,
            telemetry,
            flight,
            slo,
            origin: Instant::now(),
        })
    }

    /// Run under the config-resolved sync policy (`scheduler.policy`,
    /// falling back to the `mode` mapping).
    pub fn run(&mut self) -> Result<ModeReport> {
        // bench mode without an explicit policy fails resolution with
        // the run_bench hint
        let policy = resolve_policy(&self.cfg)?;
        self.run_policy(policy)
    }

    /// THE scheduler: the one trainer-step loop and (via
    /// [`ExplorerDriver::run`]) the one explorer loop in the system.
    /// Every coordination pattern is a [`SyncPolicy`] value.
    pub fn run_policy(&mut self, policy: Arc<dyn SyncPolicy>) -> Result<ModeReport> {
        let cfg = self.cfg.clone();
        let mut trainer = self.trainer.take().context("trainer already consumed")?;
        let plan = policy.explorer_plan(cfg.total_steps);
        let launched: &[Arc<Explorer>] = match plan {
            ExplorerPlan::None => &[],
            _ => &self.explorers,
        };
        for explorer in launched {
            explorer.reset_utilization();
        }

        let recorder = Arc::new(RunRecorder::with_observer(
            Arc::clone(&self.monitor),
            self.origin,
            self.observer.clone(),
        ));
        let state = Arc::new(WatchCell::new(RunState::default()));
        let cancel = CancellationToken::new();

        // hand the live gauge hub to the policy (no-op default) and
        // prepare the cadence-gated publisher the trainer loop drives
        if let Some(hub) = &self.telemetry {
            policy.connect_telemetry(hub);
        }

        // the adaptive control plane ([control]; DESIGN.md §9):
        // controllers step off the gauge hub lazily from the explorer
        // drivers' read paths, so no extra thread is spawned
        let control = match &self.telemetry {
            Some(hub) if cfg.control.enabled => {
                let ctx = ControlContext {
                    replicas: if cfg.service.enabled {
                        cfg.service.replicas
                    } else {
                        self.explorers.len().max(1)
                    },
                    session_rows: if cfg.service.enabled && cfg.service.max_batch > 0 {
                        cfg.service.max_batch
                    } else {
                        self.engine.gen_shape().0
                    },
                    repeat_times: cfg.repeat_times,
                    explorer_count: cfg.explorer_count,
                    batch_tasks: cfg.batch_tasks,
                    max_buffer_depth: cfg.scheduler.max_buffer_depth,
                    class_caps: cfg.qos.to_qos_config().class_caps,
                };
                let plane = ControlPlane::new(
                    cfg.control.to_control_config(),
                    ctx,
                    Arc::clone(hub),
                    self.observer.clone(),
                );
                // an adaptive policy hands its staleness controller to
                // the plane here (no-op default for static policies)
                policy.connect_control(&plane);
                // flight dumps then carry the control decision ring
                if let Some(f) = &self.flight {
                    f.attach(plane.flight_source());
                }
                Some(plane)
            }
            _ => None,
        };

        let publish_gauges = |depth: u64| {
            let Some(hub) = &self.telemetry else { return };
            if !hub.due(Instant::now()) {
                return;
            }
            let mut g = Gauges { buffer_depth: depth as f64, ..Default::default() };
            g.sample_wait_p95_s = recorder.sample_wait_p95();
            if let Some(svc) = &self.service {
                let s = svc.snapshot();
                g.queued = s.queued as f64;
                g.inflight = s.inflight as f64;
                g.occupancy = s.occupancy();
                g.quarantined = s.quarantined() as f64;
                g.queue_wait_p95_s = s.queue_wait.percentile(0.95);
                g.rollout_p95_s = s.rollout.percentile(0.95);
                g.weight_version =
                    s.replicas.iter().map(|r| r.weight_version).min().unwrap_or(0) as f64;
                {
                    use crate::qos::RequestClass;
                    g.eval_queued = svc.class_queued(RequestClass::Eval) as f64;
                    g.interactive_queued = svc.class_queued(RequestClass::Interactive) as f64;
                    g.interactive_wait_p95_s =
                        s.class_queue_wait[RequestClass::Interactive.index()].percentile(0.95);
                    if let Some(slo) = &self.slo {
                        let burn = slo.assess(&s.class_queue_wait);
                        g.slo_burn_train = burn[RequestClass::TrainRollout.index()];
                        g.slo_burn_eval = burn[RequestClass::Eval.index()];
                        g.slo_burn_interactive = burn[RequestClass::Interactive.index()];
                        if let Some(f) = &self.flight {
                            let threshold = f.config().burn_threshold;
                            if threshold > 0.0 {
                                for class in crate::qos::RequestClass::ALL {
                                    let b = burn[class.index()];
                                    if b >= threshold {
                                        f.trigger(
                                            Anomaly::SloBurn,
                                            &format!(
                                                "{} burn {b:.2} >= threshold {threshold:.2}",
                                                class.as_str()
                                            ),
                                        );
                                    }
                                }
                            }
                        }
                    }
                }
                if let Some(c) = &s.cache {
                    g.cache_hit_rate = c.hit_rate();
                    g.parked = c.parked as f64;
                    g.migrations = c.migrations as f64;
                }
            }
            hub.publish(g);
        };

        // ---- explorer drivers (scheduler pool, one worker each) ----
        let mut pool: Option<ThreadPool> = None;
        let mut promises: Vec<Promise<Result<u64>>> = vec![];
        if !launched.is_empty() {
            let p = ThreadPool::new("scheduler", launched.len());
            // multi-explorer runs hash-partition the shared task stream
            // so explorers stop duplicating curriculum order; shards
            // route tasks owned by their peers (see ShardRouter for the
            // bounded-pending semantics)
            let shards = (launched.len() > 1 && cfg.scheduler.shard_tasks)
                .then(|| ShardedTaskSource::partition(Arc::clone(&self.task_source), launched.len()));
            for (shard, explorer) in launched.iter().enumerate() {
                let source: Arc<dyn TaskSource> = match &shards {
                    Some(s) => Arc::clone(&s[shard]) as Arc<dyn TaskSource>,
                    None => Arc::clone(&self.task_source),
                };
                let driver = ExplorerDriver {
                    explorer: Arc::clone(explorer),
                    source,
                    sync: Arc::clone(&self.sync),
                    policy: Arc::clone(&policy),
                    recorder: Arc::clone(&recorder),
                    state: Arc::clone(&state),
                    cancel: cancel.clone(),
                    batch_tasks: cfg.batch_tasks,
                    control: control.clone(),
                    plan,
                    role: format!("explorer-{}", explorer.id),
                };
                promises.push(p.submit(move || driver.run()));
            }
            pool = Some(p);
        }

        // ---- trainer driver (this thread) ----
        let mut drive = || -> Result<()> {
            for t in 0..cfg.total_steps {
                let t0 = Instant::now();
                let m = trainer.train_step()?;
                recorder.trainer_step(t, &m, t0, Instant::now());
                if policy.publish_after(t + 1) {
                    let s0 = Instant::now();
                    let publish = trainer.publish_weights(self.sync.as_ref())?;
                    // keep-N rotation so long async runs stop filling
                    // the sync dir (no-op for non-durable methods)
                    if cfg.scheduler.keep_checkpoints > 0 {
                        self.sync.rotate(cfg.scheduler.keep_checkpoints)?;
                    }
                    recorder.weight_publish(s0, Instant::now(), &publish);
                    state.update(|st| st.progress.published_windows += 1);
                    if let Some(svc) = &self.service {
                        recorder.service(t + 1, &svc.snapshot());
                    }
                    if let Some(plane) = &control {
                        recorder.control(t + 1, &plane.snapshot());
                    }
                }
                // refresh the policy-visible buffer depth every step:
                // consumption (this train step) relieves the pressure
                // buffer-gated policies admit against, and the update
                // wakes blocked admission waiters
                let depth = self.buffer.ready_len() as u64;
                state.update(|st| {
                    st.progress.trainer_steps += 1;
                    st.progress.buffer_depth = depth;
                });
                publish_gauges(depth);
                if cfg.eval_every > 0 && (t + 1) % cfg.eval_every == 0 {
                    recorder.snapshot(t + 1, trainer.params().snapshot()?);
                }
            }
            Ok(())
        };
        let train_result = drive();

        // ---- shutdown ----
        // Free-running explorers are cancelled and unblocked (a closed
        // buffer fails in-flight writes); budgeted explorers finish
        // their remaining batches — every window they can wait on is
        // already published.  The state update wakes admission waiters
        // either way (and releases them all on trainer failure).
        if plan == ExplorerPlan::FreeRun {
            cancel.cancel();
            self.buffer.close();
        }
        state.update(|st| st.failed |= train_result.is_err());

        let mut explore_batches = 0u64;
        let mut explorer_err: Option<anyhow::Error> = None;
        for p in promises {
            match p.wait() {
                Ok(Ok(n)) => explore_batches += n,
                Ok(Err(e)) => explorer_err = Some(e),
                Err(e) => explorer_err = Some(anyhow!(e)),
            }
        }
        drop(pool);
        train_result.context("trainer loop failed")?;
        if let Some(e) = explorer_err {
            return Err(e.context("explorer loop failed"));
        }

        let explorer_util = match launched.len() {
            0 => 0.0,
            n => launched.iter().map(|e| e.utilization_percent()).sum::<f64>() / n as f64,
        };
        let recorder = Arc::try_unwrap(recorder)
            .map_err(|_| anyhow!("recorder still shared after drivers joined"))?;
        // final service telemetry rides on the report only — publish
        // boundaries already logged the monitor series, and logging the
        // same step twice would duplicate points
        let final_service = self.service.as_ref().map(|svc| svc.snapshot());
        let mut report = recorder.finish(
            policy.label(self.explorers.len()),
            &trainer,
            explore_batches,
            explorer_util,
            self.client.total_exec_seconds(),
        );
        report.service = final_service;
        report.control = control.as_ref().map(|plane| plane.snapshot());
        // drain the span ring into a Chrome trace-event file (viewable
        // in chrome://tracing / Perfetto, summarized by `trinity trace`)
        // and attribute the slowest episodes' wall time from the same
        // drained spans (`trinity doctor` re-derives this offline)
        if let Some(spans) = &self.observer {
            let drained = spans.drain();
            let obs_cfg = cfg.observability.to_obs_config();
            let mut paths = attribute(&drained);
            paths.truncate(obs_cfg.critical_top_k);
            report.critical_paths = paths;
            let dest = obs_cfg
                .trace_path
                .or_else(|| cfg.monitor_dir.as_ref().map(|d| d.join("trace.json")));
            if let Some(dest) = dest {
                match write_trace(&dest, &drained) {
                    Ok(()) => report.trace_path = Some(dest),
                    Err(e) => {
                        crate::log_warn!("scheduler", "trace export to {dest:?} failed: {e:#}")
                    }
                }
            }
        }
        if let Some(f) = &self.flight {
            report.flight = Some(FlightStats {
                triggers: f.triggers(),
                dumps: f.dumps(),
                suppressed: f.suppressed(),
            });
        }
        self.trainer = Some(trainer);
        Ok(report)
    }

    /// Bench mode: evaluate the explorer's current weights (or a loaded
    /// snapshot) on benchmark tiers; Avg@K per tier.
    pub fn run_bench(
        &self,
        tiers: &[&str],
        tasks_per_tier: usize,
        repeat_times: usize,
        temperature: f32,
    ) -> Result<Vec<(String, EvalReport)>> {
        let explorer = &self.explorers[0];
        let mut out = Vec::with_capacity(tiers.len());
        for tier in tiers {
            let tasks =
                super::tasks::benchmark_tasks(tier, tasks_per_tier, repeat_times, self.cfg.seed ^ 0xbe);
            let report = explorer.evaluate(&tasks, temperature)?;
            out.push((tier.to_string(), report));
        }
        Ok(out)
    }

    /// Load a weight snapshot into every explorer (bench over checkpoints).
    /// Service-backed explorers share the replica pool, so one pass over
    /// the pool covers them all.
    pub fn load_explorer_snapshot(&self, snapshot: &WeightSnapshot, version: u64) -> Result<()> {
        if let Some(svc) = &self.service {
            return svc.set_weights(snapshot, version);
        }
        for e in &self.explorers {
            e.set_weights(snapshot, version)?;
        }
        Ok(())
    }

    /// `load_explorer_snapshot` from raw leaf vectors (convenience for
    /// callers holding a plain `Vec<Vec<f32>>` snapshot).
    pub fn load_explorer_weights(&self, weights: &[Vec<f32>], version: u64) -> Result<()> {
        self.load_explorer_snapshot(&WeightSnapshot::from_weights(weights), version)
    }

    /// Start trainer AND all explorers from an externally produced weight
    /// snapshot (e.g. [`sft_warmup_snapshot`]).
    pub fn load_initial_weights(&mut self, weights: &[Vec<f32>]) -> Result<()> {
        self.trainer
            .as_mut()
            .context("trainer already consumed")?
            .load_weights(weights, 1, true)?;
        self.load_explorer_weights(weights, 1)
    }
}

/// Convenience entry point: build + run from a config.
pub fn run_mode(cfg: RftConfig) -> Result<ModeReport> {
    let mut session = RftSession::build(cfg, None, None)?;
    session.run()
}

/// SFT warm-up producing a weight snapshot (the paper's
/// `sft_warmup_dataset` pattern): a cold random model emits no valid
/// answers, so GRPO's group rewards are all zero and carry no gradient;
/// a short supervised phase on gold answers breaks the degeneracy.
/// Learning benches and the e2e example start from this snapshot.
pub fn sft_warmup_snapshot(preset: &str, seed: u64, steps: u64) -> Result<Vec<Vec<f32>>> {
    use crate::data::formatter::{FormatSpec, Formatter};
    use crate::envs::math::MathTaskGen;
    use crate::util::json::Value;

    let mut cfg = RftConfig::default();
    cfg.mode = "train".into();
    cfg.algorithm = "sft".into();
    cfg.model_preset = preset.into();
    cfg.total_steps = steps;
    cfg.seed = seed;
    cfg.hyper.lr = 2e-3;
    let mut session = RftSession::build(cfg, None, None)?;
    let formatter =
        Formatter { spec: FormatSpec::default(), tokenizer: Arc::clone(&session.tokenizer) };
    let (b, _, _) = session.engine.train_shape("sft")?;
    let mut gen = MathTaskGen::new(seed ^ 0x5f7, "warmup");
    let mut exps = Vec::with_capacity(steps as usize * b);
    for _ in 0..(steps as usize * b) {
        let t = gen.gen(1);
        let raw = Value::obj(vec![
            ("question", Value::str(t.question.clone())),
            ("answer", Value::str(t.answer.to_string())),
        ]);
        exps.push(formatter.to_expert_experience(&raw)?);
    }
    session.buffer.write(exps)?;
    session.run()?;
    session.trainer.as_ref().unwrap().params().snapshot()
}
