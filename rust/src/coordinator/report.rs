//! Run reporting: the Fig.-4-style timeline, the per-run [`ModeReport`],
//! and the [`RunRecorder`] that consolidates what the three seed mode
//! loops each plumbed by hand — monitor logging, timeline events, eval
//! snapshots, and utilization accounting.  Every policy goes through the
//! same recorder, so async runs no longer drop trainer `compute_s` from
//! the logs or weight-sync spans from the timeline.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::explorer::{EvalReport, RunnerStats};
use crate::obs::{HistSnapshot, Histogram, Span, SpanKind, SpanRecorder, NO_REPLICA};
use crate::service::ServiceSnapshot;
use crate::trainer::{PublishStats, StepMetrics, Trainer};

use super::monitor::Monitor;

/// One span on the Fig.-4-style timeline.
#[derive(Debug, Clone)]
pub struct TimelineEvent {
    pub role: String,
    pub kind: String,
    pub index: u64,
    pub start_s: f64,
    pub end_s: f64,
}

#[derive(Debug, Default)]
pub struct ModeReport {
    pub mode: String,
    pub wall_s: f64,
    pub train_steps: u64,
    pub explore_batches: u64,
    pub sync_count: u64,
    /// Explorer worker-pool busy fraction, percent (GPU-util analog).
    pub explorer_util: f64,
    /// Trainer compute fraction of wall time, percent.
    pub trainer_util: f64,
    /// Combined PJRT busy fraction, percent (GPU-power analog).
    pub device_busy: f64,
    /// Largest observed explorer weight-version lag, in publish windows
    /// (the off-policyness a `BoundedStaleness` policy bounds).
    pub max_version_lag: u64,
    pub trainer_metrics: Vec<StepMetrics>,
    pub timeline: Vec<TimelineEvent>,
    /// (step, weights) snapshots taken every `eval_every` steps.
    pub snapshots: Vec<(u64, Vec<Vec<f32>>)>,
    pub final_eval: Option<EvalReport>,
    /// End-of-run rollout-service telemetry (service-backed runs only).
    /// Carries queue-wait / rollout / prefill latency histograms, so
    /// `report.service.unwrap().queue_wait.p50_p95_p99()` gives tails.
    pub service: Option<ServiceSnapshot>,
    /// Trainer-side sample-wait latency distribution (seconds the
    /// trainer blocked on the buffer per step).
    pub sample_wait: HistSnapshot,
    /// End-of-run control-plane state (`[control]` enabled runs only):
    /// decision counts and the live outputs of every controller.
    pub control: Option<crate::control::ControlSnapshot>,
    /// Where the Chrome trace-event file was written, when observability
    /// was enabled and the run exported one.
    pub trace_path: Option<PathBuf>,
    /// Critical-path breakdowns of the slowest episodes (observability
    /// runs only; at most `critical_top_k` entries, slowest first).
    pub critical_paths: Vec<crate::obs::EpisodeBreakdown>,
    /// Flight-recorder activity over the run (diagnostics-enabled runs
    /// only): "47 anomalies, 8 dumped" on the report line.
    pub flight: Option<FlightStats>,
}

/// Flight-recorder lifetime counters for the run report.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FlightStats {
    /// Anomaly triggers observed (dumped or suppressed).
    pub triggers: u64,
    /// Dumps actually written.
    pub dumps: u64,
    /// Triggers swallowed by the rate limit or the dump cap.
    pub suppressed: u64,
}

impl ModeReport {
    pub fn series(&self, metric: &str) -> Vec<f64> {
        self.trainer_metrics
            .iter()
            .filter_map(|m| m.get(metric).map(|v| v as f64))
            .collect()
    }
    pub fn reward_series(&self) -> Vec<f64> {
        self.trainer_metrics.iter().map(|m| m.mean_reward).collect()
    }
    pub fn response_len_series(&self) -> Vec<f64> {
        self.trainer_metrics.iter().map(|m| m.mean_response_len).collect()
    }
}

/// One completed rollout batch, as reported by an explorer driver.
pub struct RolloutRecord<'a> {
    pub role: &'a str,
    pub batch: u64,
    pub stats: &'a RunnerStats,
    /// Weight version the batch was generated with (post-pull).
    pub weight_version: u64,
    /// Publish-windows this version trails the batch's window.
    pub version_lag: u64,
}

/// Per-run event sink shared by the trainer driver and all explorer
/// drivers; [`RunRecorder::finish`] assembles the [`ModeReport`].
pub struct RunRecorder {
    monitor: Arc<Monitor>,
    /// Session origin, so timelines stay monotonic across `run()` calls.
    origin: Instant,
    run_start: Instant,
    timeline: Mutex<Vec<TimelineEvent>>,
    snapshots: Mutex<Vec<(u64, Vec<Vec<f32>>)>>,
    compute_total: Mutex<f64>,
    sync_count: AtomicU64,
    max_version_lag: AtomicU64,
    /// Trainer sample-wait distribution (p50/p95/p99 in the report).
    sample_wait: Histogram,
    /// Episode span sink; weight syncs land here as `weight_sync` spans
    /// so the exported trace shows the stall alongside rollout activity.
    obs: Option<Arc<SpanRecorder>>,
}

impl RunRecorder {
    pub fn new(monitor: Arc<Monitor>, origin: Instant) -> RunRecorder {
        Self::with_observer(monitor, origin, None)
    }

    /// A recorder that additionally mirrors weight syncs into the span
    /// recorder (observability enabled).
    pub fn with_observer(
        monitor: Arc<Monitor>,
        origin: Instant,
        obs: Option<Arc<SpanRecorder>>,
    ) -> RunRecorder {
        RunRecorder {
            monitor,
            origin,
            run_start: Instant::now(),
            timeline: Mutex::new(vec![]),
            snapshots: Mutex::new(vec![]),
            compute_total: Mutex::new(0.0),
            sync_count: AtomicU64::new(0),
            max_version_lag: AtomicU64::new(0),
            sample_wait: Histogram::new(),
            obs,
        }
    }

    fn span(&self, role: &str, kind: &str, index: u64, start: Instant, end: Instant) {
        self.timeline.lock().unwrap().push(TimelineEvent {
            role: role.to_string(),
            kind: kind.to_string(),
            index,
            start_s: start.duration_since(self.origin).as_secs_f64(),
            end_s: end.duration_since(self.origin).as_secs_f64(),
        });
    }

    /// One completed trainer step: timeline span + the uniform monitor
    /// field set (every policy logs the same keys).
    pub fn trainer_step(&self, index: u64, m: &StepMetrics, start: Instant, end: Instant) {
        self.span("trainer", "train", index, start, end);
        *self.compute_total.lock().unwrap() += m.compute_s;
        self.sample_wait.observe(m.sample_wait_s);
        let mut logs: Vec<(String, f64)> = vec![
            ("reward".into(), m.mean_reward),
            ("response_len".into(), m.mean_response_len),
            ("sample_wait_s".into(), m.sample_wait_s),
            ("compute_s".into(), m.compute_s),
        ];
        logs.extend(m.named.iter().map(|(n, v)| (n.clone(), *v as f64)));
        self.monitor.log("trainer", m.step, &logs);
    }

    /// One completed weight publish; returns the running sync count.
    pub fn weight_sync(&self, start: Instant, end: Instant) -> u64 {
        let count = self.sync_count.fetch_add(1, Ordering::SeqCst) + 1;
        self.span("trainer", "weight_sync", count, start, end);
        if let Some(o) = &self.obs {
            o.record(Span {
                trace: 0,
                kind: SpanKind::SyncStall,
                replica: NO_REPLICA,
                start_us: o.rel_us(start),
                dur_us: end.saturating_duration_since(start).as_micros() as u64,
                detail: count,
            });
        }
        count
    }

    /// One completed weight publish with its [`PublishStats`]: the
    /// timeline/span bookkeeping of [`weight_sync`](Self::weight_sync)
    /// plus the snapshot-reuse telemetry (total vs reused leaves, trainer
    /// stall) under the "trainer" role.
    pub fn weight_publish(&self, start: Instant, end: Instant, stats: &PublishStats) -> u64 {
        let count = self.weight_sync(start, end);
        self.monitor.log(
            "trainer",
            stats.version,
            &[
                ("publish_total_leaves".into(), stats.total_leaves as f64),
                ("publish_reused_leaves".into(), stats.reused_leaves as f64),
                ("publish_stall_s".into(), stats.stall_s),
            ],
        );
        count
    }

    /// One completed explorer rollout batch, with the weight version it
    /// ran at and its version lag in publish windows.
    pub fn rollout(&self, rec: &RolloutRecord<'_>, start: Instant, end: Instant) {
        self.span(rec.role, "rollout", rec.batch, start, end);
        self.max_version_lag.fetch_max(rec.version_lag, Ordering::SeqCst);
        self.monitor.log(
            rec.role,
            rec.batch,
            &[
                ("experiences".into(), rec.stats.experiences as f64),
                ("skipped".into(), rec.stats.skipped as f64),
                ("batch_s".into(), (end - start).as_secs_f64()),
                ("weight_version".into(), rec.weight_version as f64),
                ("version_lag".into(), rec.version_lag as f64),
            ],
        );
    }

    pub fn snapshot(&self, step: u64, weights: Vec<Vec<f32>>) {
        self.snapshots.lock().unwrap().push((step, weights));
    }

    /// Log rollout-service telemetry under the "service" role (the
    /// scheduler calls this at publish boundaries and at run end).
    pub fn service(&self, step: u64, snap: &ServiceSnapshot) {
        self.monitor.log("service", step, &snap.monitor_fields());
    }

    /// Log control-plane state under the "control" role (the scheduler
    /// calls this at publish boundaries and at run end).
    pub fn control(&self, step: u64, snap: &crate::control::ControlSnapshot) {
        self.monitor.log("control", step, &snap.monitor_fields());
    }

    /// Trainer sample-wait p95 so far, seconds (the staleness
    /// controller's starvation signal; gauge `sample_wait_p95_s`).
    pub fn sample_wait_p95(&self) -> f64 {
        self.sample_wait.snapshot().percentile(0.95)
    }

    pub fn sync_count(&self) -> u64 {
        self.sync_count.load(Ordering::SeqCst)
    }

    /// Assemble the report.  `device_exec_seconds` is the PJRT busy time
    /// over the run (clamped to wall for the busy fraction).
    pub fn finish(
        self,
        label: String,
        trainer: &Trainer,
        explore_batches: u64,
        explorer_util: f64,
        device_exec_seconds: f64,
    ) -> ModeReport {
        let wall = self.run_start.elapsed().as_secs_f64();
        ModeReport {
            mode: label,
            wall_s: wall,
            train_steps: trainer.step(),
            explore_batches,
            sync_count: self.sync_count.load(Ordering::SeqCst),
            explorer_util,
            trainer_util: 100.0 * *self.compute_total.lock().unwrap() / wall,
            device_busy: 100.0 * device_exec_seconds.min(wall) / wall,
            max_version_lag: self.max_version_lag.load(Ordering::SeqCst),
            trainer_metrics: trainer.history().to_vec(),
            timeline: self.timeline.into_inner().unwrap(),
            snapshots: self.snapshots.into_inner().unwrap(),
            final_eval: None,
            service: None,
            sample_wait: self.sample_wait.snapshot(),
            control: None,
            trace_path: None,
            critical_paths: vec![],
            flight: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn recorder_accumulates_spans_and_lag() {
        let rec = RunRecorder::new(Arc::new(Monitor::in_memory()), Instant::now());
        let t0 = Instant::now();
        std::thread::sleep(Duration::from_millis(5));
        let t1 = Instant::now();
        let stats = RunnerStats { completed: 1, experiences: 4, ..Default::default() };
        rec.rollout(
            &RolloutRecord {
                role: "explorer-0",
                batch: 0,
                stats: &stats,
                weight_version: 1,
                version_lag: 2,
            },
            t0,
            t1,
        );
        rec.rollout(
            &RolloutRecord {
                role: "explorer-1",
                batch: 0,
                stats: &stats,
                weight_version: 2,
                version_lag: 1,
            },
            t0,
            t1,
        );
        assert_eq!(rec.weight_sync(t0, t1), 1);
        assert_eq!(rec.weight_sync(t0, t1), 2);
        assert_eq!(rec.sync_count(), 2);
        rec.snapshot(2, vec![vec![1.0]]);
        assert_eq!(rec.max_version_lag.load(Ordering::SeqCst), 2);
        let events = rec.timeline.lock().unwrap().clone();
        assert_eq!(events.len(), 4);
        assert!(events.iter().all(|e| e.end_s >= e.start_s));
        assert!(events.iter().any(|e| e.kind == "weight_sync" && e.role == "trainer"));
    }

    #[test]
    fn weight_publish_logs_reuse_telemetry() {
        let monitor = Arc::new(Monitor::in_memory());
        let rec = RunRecorder::new(Arc::clone(&monitor), Instant::now());
        let t0 = Instant::now();
        let stats =
            PublishStats { version: 3, total_leaves: 8, reused_leaves: 6, stall_s: 0.01 };
        assert_eq!(rec.weight_publish(t0, Instant::now(), &stats), 1);
        assert_eq!(rec.sync_count(), 1, "weight_publish counts as a sync");
        assert_eq!(monitor.series_values("trainer/publish_total_leaves"), vec![8.0]);
        assert_eq!(monitor.series_values("trainer/publish_reused_leaves"), vec![6.0]);
        assert_eq!(monitor.series("trainer/publish_stall_s").len(), 1);
    }

    #[test]
    fn recorder_logs_service_snapshots_under_service_role() {
        let monitor = Arc::new(Monitor::in_memory());
        let rec = RunRecorder::new(Arc::clone(&monitor), Instant::now());
        let snap = ServiceSnapshot { sessions: 2, rows: 6, ..Default::default() };
        rec.service(1, &snap);
        assert_eq!(monitor.series_values("service/occupancy"), vec![3.0]);
        assert_eq!(monitor.series("service/queued").len(), 1);
    }

    #[test]
    fn recorder_logs_control_snapshots_under_control_role() {
        let monitor = Arc::new(Monitor::in_memory());
        let rec = RunRecorder::new(Arc::clone(&monitor), Instant::now());
        let snap = crate::control::ControlSnapshot {
            decisions: 3,
            stale_holds: 0,
            admission_open: true,
            pressure: 0.4,
            batch_tasks: 2,
            staleness_lag: Some(1),
            recent: vec![],
        };
        rec.control(7, &snap);
        assert_eq!(monitor.series_values("control/decisions"), vec![3.0]);
        assert_eq!(monitor.series_values("control/admission_open"), vec![1.0]);
        assert_eq!(monitor.series_values("control/staleness_lag"), vec![1.0]);
    }

    #[test]
    fn sample_wait_p95_tracks_the_live_histogram() {
        let rec = RunRecorder::new(Arc::new(Monitor::in_memory()), Instant::now());
        assert_eq!(rec.sample_wait_p95(), 0.0, "empty histogram reads 0");
        let now = Instant::now();
        for (i, wait) in [0.010, 0.010, 0.010, 0.200].iter().enumerate() {
            let m = StepMetrics {
                step: i as u64 + 1,
                named: vec![],
                mean_reward: 0.0,
                mean_response_len: 0.0,
                sample_wait_s: *wait,
                compute_s: 0.0,
            };
            rec.trainer_step(m.step, &m, now, now);
        }
        let p95 = rec.sample_wait_p95();
        assert!(p95 > 0.05, "p95 must see the slow tail, got {p95}");
    }

    #[test]
    fn timeline_stays_monotonic_across_consecutive_runs() {
        // The session reuses one origin across `run()` calls, so a later
        // run's recorder must place its spans after the earlier run's.
        let origin = Instant::now();
        let monitor = Arc::new(Monitor::in_memory());
        let stats = RunnerStats::default();
        let record = |rec: &RunRecorder| {
            let t0 = Instant::now();
            std::thread::sleep(Duration::from_millis(2));
            let t1 = Instant::now();
            rec.rollout(
                &RolloutRecord {
                    role: "explorer-0",
                    batch: 0,
                    stats: &stats,
                    weight_version: 0,
                    version_lag: 0,
                },
                t0,
                t1,
            );
            rec.weight_sync(t0, t1);
            rec.timeline.lock().unwrap().clone()
        };
        let first = record(&RunRecorder::new(Arc::clone(&monitor), origin));
        std::thread::sleep(Duration::from_millis(2));
        let second = record(&RunRecorder::new(Arc::clone(&monitor), origin));
        let first_end = first.iter().map(|e| e.end_s).fold(0.0, f64::max);
        for e in first.iter().chain(second.iter()) {
            assert!(e.start_s >= 0.0 && e.end_s >= e.start_s, "span ordered: {e:?}");
        }
        for e in &second {
            assert!(
                e.start_s >= first_end,
                "second run span at {} precedes first run end {first_end}",
                e.start_s
            );
        }
    }

    #[test]
    fn weight_sync_mirrors_into_span_recorder() {
        let spans = Arc::new(SpanRecorder::new(16));
        let rec = RunRecorder::with_observer(
            Arc::new(Monitor::in_memory()),
            Instant::now(),
            Some(Arc::clone(&spans)),
        );
        let t0 = Instant::now();
        std::thread::sleep(Duration::from_millis(2));
        rec.weight_sync(t0, Instant::now());
        let drained = spans.drain();
        assert_eq!(drained.len(), 1);
        assert_eq!(drained[0].kind, SpanKind::SyncStall);
        assert_eq!(drained[0].replica, NO_REPLICA);
        assert_eq!(drained[0].detail, 1, "detail carries the sync count");
        assert!(drained[0].dur_us >= 1_000, "sleep visible: {}", drained[0].dur_us);
    }

    #[test]
    fn trainer_step_feeds_sample_wait_histogram() {
        let rec = RunRecorder::new(Arc::new(Monitor::in_memory()), Instant::now());
        let now = Instant::now();
        for (i, wait) in [0.010, 0.020, 0.040].iter().enumerate() {
            let m = StepMetrics {
                step: i as u64 + 1,
                named: vec![],
                mean_reward: 0.0,
                mean_response_len: 0.0,
                sample_wait_s: *wait,
                compute_s: 0.001,
            };
            rec.trainer_step(m.step, &m, now, now);
        }
        let snap = rec.sample_wait.snapshot();
        assert_eq!(snap.count, 3);
        assert!((snap.sum_s - 0.070).abs() < 1e-9);
        let (p50, _p95, p99) = snap.p50_p95_p99();
        assert!(p50 > 0.0 && p99 >= p50);
    }

    #[test]
    fn service_and_cache_telemetry_survive_into_mode_report() {
        // Mimics the scheduler's `report.service = Some(svc.snapshot())`
        // hand-off: histogram tails and cache counters stay readable on
        // the final report.
        let metrics = crate::service::ServiceMetrics::new();
        let eval = crate::qos::RequestClass::Eval;
        for ms in [5u64, 10, 20, 40] {
            metrics.note_queue_wait(Duration::from_millis(ms), eval);
            metrics.note_rollout(Duration::from_millis(ms * 3), eval);
        }
        let mut snap = ServiceSnapshot {
            sessions: 2,
            rows: 6,
            queue_wait: metrics.queue_wait.snapshot(),
            rollout: metrics.rollout.snapshot(),
            class_queue_wait: std::array::from_fn(|i| metrics.class_queue_wait[i].snapshot()),
            ..Default::default()
        };
        snap.cache = Some(crate::cache::CacheSnapshot {
            lookups: 10,
            hits: 7,
            misses: 3,
            parked: 2,
            ..Default::default()
        });
        let report = ModeReport { service: Some(snap), ..Default::default() };
        let svc = report.service.as_ref().unwrap();
        let (p50, p95, p99) = svc.queue_wait.p50_p95_p99();
        assert!(p50 > 0.0 && p95 >= p50 && p99 >= p95, "{p50} {p95} {p99}");
        assert_eq!(svc.rollout.count, 4);
        // per-class split survives the hand-off too
        assert_eq!(svc.class_queue_wait[eval.index()].count, 4);
        assert_eq!(svc.class_queue_wait[crate::qos::RequestClass::Interactive.index()].count, 0);
        let cache = svc.cache.as_ref().unwrap();
        assert!((cache.hit_rate() - 0.7).abs() < 1e-12);
        assert_eq!(cache.parked, 2);
        assert_eq!(report.sample_wait.count, 0, "no trainer steps recorded");
        assert!(report.trace_path.is_none());
    }

    #[test]
    fn recorder_monitor_gets_uniform_rollout_fields() {
        let monitor = Arc::new(Monitor::in_memory());
        let rec = RunRecorder::new(Arc::clone(&monitor), Instant::now());
        let now = Instant::now();
        let stats = RunnerStats::default();
        rec.rollout(
            &RolloutRecord {
                role: "explorer-0",
                batch: 3,
                stats: &stats,
                weight_version: 5,
                version_lag: 0,
            },
            now,
            now,
        );
        for key in
            ["experiences", "skipped", "batch_s", "weight_version", "version_lag"]
        {
            assert_eq!(
                monitor.series(&format!("explorer-0/{key}")).len(),
                1,
                "missing rollout field {key}"
            );
        }
        assert_eq!(monitor.series_values("explorer-0/weight_version"), vec![5.0]);
    }
}
