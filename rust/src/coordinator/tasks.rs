//! Task sources: where the explorer's work comes from.  The default
//! sources wrap the synthetic envs; `PrioritizedTaskSource` serves a
//! pre-curated, priority-ordered task set produced by the data pipeline
//! (curriculum learning, Fig. 10).

use std::sync::Mutex;

use crate::envs::math::MathTaskGen;
use crate::explorer::Task;
use crate::util::json::Value;

pub trait TaskSource: Send + Sync {
    /// Next batch of `n` tasks (each expanded to `repeat_times` rollouts
    /// by its workflow).
    fn next_batch(&self, n: usize) -> Vec<Task>;
    /// A held-out evaluation batch (disjoint from training tasks).
    fn eval_batch(&self, n: usize) -> Vec<Task>;
}

/// Synthetic verifiable-math tasks in a difficulty band.
pub struct MathTaskSource {
    gen: Mutex<MathTaskGen>,
    eval_gen: Mutex<MathTaskGen>,
    pub min_difficulty: usize,
    pub max_difficulty: usize,
    pub repeat_times: usize,
}

impl MathTaskSource {
    pub fn new(seed: u64, min_d: usize, max_d: usize, repeat_times: usize) -> MathTaskSource {
        MathTaskSource {
            gen: Mutex::new(MathTaskGen::new(seed, "train")),
            eval_gen: Mutex::new(MathTaskGen::new(seed, "eval")),
            min_difficulty: min_d,
            max_difficulty: max_d,
            repeat_times,
        }
    }

    fn make(&self, gen: &Mutex<MathTaskGen>, n: usize) -> Vec<Task> {
        let mut g = gen.lock().unwrap();
        g.gen_batch(n, self.min_difficulty, self.max_difficulty)
            .into_iter()
            .map(|mt| {
                let mut t = Task::new(&mt.id, "math", mt.to_payload());
                t.difficulty = mt.difficulty as f64;
                t.repeat_times = self.repeat_times;
                t
            })
            .collect()
    }
}

impl TaskSource for MathTaskSource {
    fn next_batch(&self, n: usize) -> Vec<Task> {
        self.make(&self.gen, n)
    }
    fn eval_batch(&self, n: usize) -> Vec<Task> {
        self.make(&self.eval_gen, n)
    }
}

/// Benchmark-tier eval sets (the AIME/AMC/MATH500 stand-ins).
pub fn benchmark_tasks(tier: &str, n: usize, repeat_times: usize, seed: u64) -> Vec<Task> {
    let (lo, hi) = MathTaskGen::benchmark_difficulty(tier);
    let mut g = MathTaskGen::new(seed, tier);
    g.gen_batch(n, lo, hi)
        .into_iter()
        .map(|mt| {
            let mut t = Task::new(&mt.id, "math", mt.to_payload());
            t.difficulty = mt.difficulty as f64;
            t.repeat_times = repeat_times;
            t
        })
        .collect()
}

/// Multi-turn grid-world episodes.
pub struct AlfworldTaskSource {
    counter: Mutex<u64>,
    pub seed: u64,
    pub repeat_times: usize,
}

impl AlfworldTaskSource {
    pub fn new(seed: u64, repeat_times: usize) -> AlfworldTaskSource {
        AlfworldTaskSource { counter: Mutex::new(0), seed, repeat_times }
    }
}

impl TaskSource for AlfworldTaskSource {
    fn next_batch(&self, n: usize) -> Vec<Task> {
        let mut c = self.counter.lock().unwrap();
        (0..n)
            .map(|_| {
                *c += 1;
                let env_seed = self.seed.wrapping_add(*c);
                let mut t = Task::new(
                    &format!("alf-{}", *c),
                    "alfworld",
                    Value::obj(vec![("seed", Value::num(env_seed as f64))]),
                );
                t.repeat_times = self.repeat_times;
                t
            })
            .collect()
    }

    fn eval_batch(&self, n: usize) -> Vec<Task> {
        (0..n)
            .map(|i| {
                let env_seed = self.seed.wrapping_add(1_000_000 + i as u64);
                let mut t = Task::new(
                    &format!("alf-eval-{i}"),
                    "alfworld",
                    Value::obj(vec![("seed", Value::num(env_seed as f64))]),
                );
                t.repeat_times = self.repeat_times;
                t
            })
            .collect()
    }
}

/// A fixed, pre-curated task list served in priority order, cycling when
/// exhausted (the output of the task-curation pipeline).
pub struct PrioritizedTaskSource {
    tasks: Vec<Task>,
    eval: Vec<Task>,
    cursor: Mutex<usize>,
}

impl PrioritizedTaskSource {
    pub fn new(tasks: Vec<Task>, eval: Vec<Task>) -> PrioritizedTaskSource {
        PrioritizedTaskSource { tasks, eval, cursor: Mutex::new(0) }
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }
}

impl TaskSource for PrioritizedTaskSource {
    fn next_batch(&self, n: usize) -> Vec<Task> {
        let mut cursor = self.cursor.lock().unwrap();
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            if self.tasks.is_empty() {
                break;
            }
            out.push(self.tasks[*cursor % self.tasks.len()].clone());
            *cursor += 1;
        }
        out
    }

    fn eval_batch(&self, n: usize) -> Vec<Task> {
        self.eval.iter().take(n).cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn math_source_batches_with_difficulty_band() {
        let s = MathTaskSource::new(1, 2, 4, 8);
        let b = s.next_batch(6);
        assert_eq!(b.len(), 6);
        for t in &b {
            assert!((2.0..=4.0).contains(&t.difficulty));
            assert_eq!(t.repeat_times, 8);
            assert!(t.payload.get("question").is_some());
        }
        // train and eval are disjoint streams
        let e = s.eval_batch(6);
        assert_ne!(
            b[0].payload.get("question").unwrap().as_str(),
            e[0].payload.get("question").unwrap().as_str()
        );
    }

    #[test]
    fn benchmark_tiers_have_expected_difficulty() {
        let t = benchmark_tasks("aime25s", 10, 4, 3);
        assert_eq!(t.len(), 10);
        assert!(t.iter().all(|x| x.difficulty >= 5.0));
        let easy = benchmark_tasks("math500s", 10, 4, 3);
        assert!(easy.iter().all(|x| x.difficulty <= 2.0));
    }

    #[test]
    fn prioritized_source_cycles_in_order() {
        let tasks: Vec<Task> = (0..3)
            .map(|i| Task::new(&format!("p{i}"), "math", Value::Object(vec![])))
            .collect();
        let s = PrioritizedTaskSource::new(tasks, vec![]);
        let b = s.next_batch(5);
        let ids: Vec<&str> = b.iter().map(|t| t.id.as_str()).collect();
        assert_eq!(ids, vec!["p0", "p1", "p2", "p0", "p1"]);
    }

    #[test]
    fn alfworld_source_unique_seeds() {
        let s = AlfworldTaskSource::new(9, 2);
        let b1 = s.next_batch(3);
        let b2 = s.next_batch(3);
        let seeds: Vec<f64> = b1
            .iter()
            .chain(&b2)
            .map(|t| t.payload.get("seed").unwrap().as_f64().unwrap())
            .collect();
        let mut uniq = seeds.clone();
        uniq.sort_by(|a, b| a.partial_cmp(b).unwrap());
        uniq.dedup();
        assert_eq!(uniq.len(), seeds.len());
    }
}
