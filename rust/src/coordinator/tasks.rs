//! Task sources: where the explorer's work comes from.  The default
//! sources wrap the synthetic envs; `PrioritizedTaskSource` serves a
//! pre-curated, priority-ordered task set produced by the data pipeline
//! (curriculum learning, Fig. 10); `ShardedTaskSource` hash-partitions a
//! shared stream across explorers so multi-explorer runs stop
//! duplicating curriculum order.

use std::sync::{Arc, Mutex};

use crate::envs::math::MathTaskGen;
use crate::explorer::Task;
use crate::util::json::Value;

pub trait TaskSource: Send + Sync {
    /// Next batch of `n` tasks (each expanded to `repeat_times` rollouts
    /// by its workflow).
    fn next_batch(&self, n: usize) -> Vec<Task>;
    /// A held-out evaluation batch (disjoint from training tasks).
    fn eval_batch(&self, n: usize) -> Vec<Task>;
}

/// Synthetic verifiable-math tasks in a difficulty band.
pub struct MathTaskSource {
    gen: Mutex<MathTaskGen>,
    eval_gen: Mutex<MathTaskGen>,
    pub min_difficulty: usize,
    pub max_difficulty: usize,
    pub repeat_times: usize,
}

impl MathTaskSource {
    pub fn new(seed: u64, min_d: usize, max_d: usize, repeat_times: usize) -> MathTaskSource {
        MathTaskSource {
            gen: Mutex::new(MathTaskGen::new(seed, "train")),
            eval_gen: Mutex::new(MathTaskGen::new(seed, "eval")),
            min_difficulty: min_d,
            max_difficulty: max_d,
            repeat_times,
        }
    }

    fn make(&self, gen: &Mutex<MathTaskGen>, n: usize) -> Vec<Task> {
        let mut g = gen.lock().unwrap();
        g.gen_batch(n, self.min_difficulty, self.max_difficulty)
            .into_iter()
            .map(|mt| {
                let mut t = Task::new(&mt.id, "math", mt.to_payload());
                t.difficulty = mt.difficulty as f64;
                t.repeat_times = self.repeat_times;
                t
            })
            .collect()
    }
}

impl TaskSource for MathTaskSource {
    fn next_batch(&self, n: usize) -> Vec<Task> {
        self.make(&self.gen, n)
    }
    fn eval_batch(&self, n: usize) -> Vec<Task> {
        self.make(&self.eval_gen, n)
    }
}

/// Benchmark-tier eval sets (the AIME/AMC/MATH500 stand-ins).
pub fn benchmark_tasks(tier: &str, n: usize, repeat_times: usize, seed: u64) -> Vec<Task> {
    let (lo, hi) = MathTaskGen::benchmark_difficulty(tier);
    let mut g = MathTaskGen::new(seed, tier);
    g.gen_batch(n, lo, hi)
        .into_iter()
        .map(|mt| {
            let mut t = Task::new(&mt.id, "math", mt.to_payload());
            t.difficulty = mt.difficulty as f64;
            t.repeat_times = repeat_times;
            t
        })
        .collect()
}

/// Multi-turn grid-world episodes.
pub struct AlfworldTaskSource {
    counter: Mutex<u64>,
    pub seed: u64,
    pub repeat_times: usize,
}

impl AlfworldTaskSource {
    pub fn new(seed: u64, repeat_times: usize) -> AlfworldTaskSource {
        AlfworldTaskSource { counter: Mutex::new(0), seed, repeat_times }
    }
}

impl TaskSource for AlfworldTaskSource {
    fn next_batch(&self, n: usize) -> Vec<Task> {
        let mut c = self.counter.lock().unwrap();
        (0..n)
            .map(|_| {
                *c += 1;
                let env_seed = self.seed.wrapping_add(*c);
                let mut t = Task::new(
                    &format!("alf-{}", *c),
                    "alfworld",
                    Value::obj(vec![("seed", Value::num(env_seed as f64))]),
                );
                t.repeat_times = self.repeat_times;
                t
            })
            .collect()
    }

    fn eval_batch(&self, n: usize) -> Vec<Task> {
        (0..n)
            .map(|i| {
                let env_seed = self.seed.wrapping_add(1_000_000 + i as u64);
                let mut t = Task::new(
                    &format!("alf-eval-{i}"),
                    "alfworld",
                    Value::obj(vec![("seed", Value::num(env_seed as f64))]),
                );
                t.repeat_times = self.repeat_times;
                t
            })
            .collect()
    }
}

/// A fixed, pre-curated task list served in priority order, cycling when
/// exhausted (the output of the task-curation pipeline).
pub struct PrioritizedTaskSource {
    tasks: Vec<Task>,
    eval: Vec<Task>,
    cursor: Mutex<usize>,
}

impl PrioritizedTaskSource {
    pub fn new(tasks: Vec<Task>, eval: Vec<Task>) -> PrioritizedTaskSource {
        PrioritizedTaskSource { tasks, eval, cursor: Mutex::new(0) }
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }
}

impl TaskSource for PrioritizedTaskSource {
    fn next_batch(&self, n: usize) -> Vec<Task> {
        let mut cursor = self.cursor.lock().unwrap();
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            if self.tasks.is_empty() {
                break;
            }
            out.push(self.tasks[*cursor % self.tasks.len()].clone());
            *cursor += 1;
        }
        out
    }

    fn eval_batch(&self, n: usize) -> Vec<Task> {
        self.eval.iter().take(n).cloned().collect()
    }
}

/// Shared state behind one partition of a task stream: the inner source
/// plus a per-shard pending queue.  Whichever shard pulls from the
/// inner source *routes* tasks it does not own to the owner's pending
/// queue, so every task id is handled by exactly one explorer and each
/// shard sees the underlying stream's order.  Routing is lossless up to
/// [`SHARD_PENDING_CAP`] queued tasks per shard; past that (a stalled or
/// much slower explorer) the oldest routed task is dropped with a debug
/// log — cycling/curated sources re-serve it a cycle later.
struct ShardRouter {
    inner: Arc<dyn TaskSource>,
    pending: Vec<Mutex<std::collections::VecDeque<Task>>>,
    count: u64,
}

/// A slow shard's pending queue is capped; overflow drops the oldest
/// routed task (cycling/curated sources re-serve it a cycle later).
const SHARD_PENDING_CAP: usize = 1024;

/// Shard `index` of a [`ShardRouter`] partition — build the full set
/// with [`ShardedTaskSource::partition`].
pub struct ShardedTaskSource {
    router: Arc<ShardRouter>,
    index: u64,
}

impl ShardedTaskSource {
    /// Hash-partition `inner` into `count` shards (one per explorer).
    pub fn partition(inner: Arc<dyn TaskSource>, count: usize) -> Vec<Arc<ShardedTaskSource>> {
        assert!(count >= 1, "need at least one shard");
        let router = Arc::new(ShardRouter {
            inner,
            pending: (0..count).map(|_| Mutex::new(std::collections::VecDeque::new())).collect(),
            count: count as u64,
        });
        (0..count)
            .map(|index| {
                Arc::new(ShardedTaskSource { router: Arc::clone(&router), index: index as u64 })
            })
            .collect()
    }

    pub fn index(&self) -> usize {
        self.index as usize
    }

    fn owner(&self, task: &Task) -> u64 {
        task.group_id() % self.router.count
    }
}

impl TaskSource for ShardedTaskSource {
    fn next_batch(&self, n: usize) -> Vec<Task> {
        let mut out = Vec::with_capacity(n);
        // first serve what other shards already routed here
        {
            let mut mine = self.router.pending[self.index as usize].lock().unwrap();
            while out.len() < n {
                match mine.pop_front() {
                    Some(t) => out.push(t),
                    None => break,
                }
            }
        }
        // then pull from the shared stream, routing misses to their
        // owners; bounded so a degenerate stream (every id on another
        // shard) yields a short batch instead of spinning
        let max_pulls = 16 * n.max(1) * self.router.count as usize;
        let mut pulled = 0usize;
        while out.len() < n && pulled < max_pulls {
            let chunk = self.router.inner.next_batch(n.max(1));
            if chunk.is_empty() {
                break;
            }
            pulled += chunk.len();
            for task in chunk {
                let owner = self.owner(&task);
                if owner == self.index && out.len() < n {
                    out.push(task);
                } else {
                    let mut q = self.router.pending[owner as usize].lock().unwrap();
                    if q.len() >= SHARD_PENDING_CAP {
                        let dropped = q.pop_front();
                        crate::log_debug!(
                            "tasks",
                            "shard {owner} pending full; dropping oldest routed task {:?}",
                            dropped.map(|t| t.id)
                        );
                    }
                    q.push_back(task);
                }
            }
        }
        out
    }

    /// Evaluation is not sharded: every explorer scores the same set.
    fn eval_batch(&self, n: usize) -> Vec<Task> {
        self.router.inner.eval_batch(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn math_source_batches_with_difficulty_band() {
        let s = MathTaskSource::new(1, 2, 4, 8);
        let b = s.next_batch(6);
        assert_eq!(b.len(), 6);
        for t in &b {
            assert!((2.0..=4.0).contains(&t.difficulty));
            assert_eq!(t.repeat_times, 8);
            assert!(t.payload.get("question").is_some());
        }
        // train and eval are disjoint streams
        let e = s.eval_batch(6);
        assert_ne!(
            b[0].payload.get("question").unwrap().as_str(),
            e[0].payload.get("question").unwrap().as_str()
        );
    }

    #[test]
    fn benchmark_tiers_have_expected_difficulty() {
        let t = benchmark_tasks("aime25s", 10, 4, 3);
        assert_eq!(t.len(), 10);
        assert!(t.iter().all(|x| x.difficulty >= 5.0));
        let easy = benchmark_tasks("math500s", 10, 4, 3);
        assert!(easy.iter().all(|x| x.difficulty <= 2.0));
    }

    #[test]
    fn prioritized_source_cycles_in_order() {
        let tasks: Vec<Task> = (0..3)
            .map(|i| Task::new(&format!("p{i}"), "math", Value::Object(vec![])))
            .collect();
        let s = PrioritizedTaskSource::new(tasks, vec![]);
        let b = s.next_batch(5);
        let ids: Vec<&str> = b.iter().map(|t| t.id.as_str()).collect();
        assert_eq!(ids, vec!["p0", "p1", "p2", "p0", "p1"]);
    }

    #[test]
    fn shards_partition_the_stream_without_duplication() {
        // one shared generator, three shards pulling from it in turn
        let inner: Arc<dyn TaskSource> = Arc::new(MathTaskSource::new(5, 1, 3, 2));
        let shards = ShardedTaskSource::partition(inner, 3);
        let mut seen: Vec<String> = vec![];
        for shard in &shards {
            for t in shard.next_batch(6) {
                assert_eq!(
                    t.group_id() % 3,
                    shard.index() as u64,
                    "task served by the wrong shard"
                );
                seen.push(t.id.clone());
            }
        }
        let mut uniq = seen.clone();
        uniq.sort();
        uniq.dedup();
        assert_eq!(uniq.len(), seen.len(), "a task id appeared on two shards");
        assert!(seen.len() >= 12, "shards should fill their batches: {}", seen.len());
    }

    #[test]
    fn routed_tasks_are_kept_for_their_owner_not_discarded() {
        // a fixed curated list: shard A's pulls must leave shard B's
        // tasks queued for B, preserving curriculum coverage
        let tasks: Vec<Task> = (0..8)
            .map(|i| Task::new(&format!("cur{i}"), "math", Value::Object(vec![])))
            .collect();
        let owned_by = |t: &Task| (t.group_id() % 2) as usize;
        let expect_b: Vec<String> =
            tasks.iter().filter(|t| owned_by(t) == 1).map(|t| t.id.clone()).collect();
        let inner: Arc<dyn TaskSource> = Arc::new(PrioritizedTaskSource::new(tasks, vec![]));
        let shards = ShardedTaskSource::partition(inner, 2);
        // shard 0 pulls first and routes shard 1's tasks to its pending
        let a = shards[0].next_batch(4);
        assert!(a.iter().all(|t| owned_by(t) == 0));
        // shard 1 then receives every one of its tasks, in stream order
        let b = shards[1].next_batch(expect_b.len());
        let b_ids: Vec<String> = b.iter().map(|t| t.id.clone()).collect();
        assert_eq!(
            b_ids[..expect_b.len().min(b_ids.len())],
            expect_b[..],
            "routed tasks must reach their owner in order"
        );
    }

    #[test]
    fn degenerate_shard_returns_short_batch_instead_of_spinning() {
        // a single repeated task id hashes to exactly one shard; the
        // other shard must give up after bounded pulls
        let only = Task::new("solo", "math", Value::Object(vec![]));
        let inner: Arc<dyn TaskSource> =
            Arc::new(PrioritizedTaskSource::new(vec![only.clone()], vec![only.clone()]));
        let owner = (only.group_id() % 2) as usize;
        let shards = ShardedTaskSource::partition(inner, 2);
        assert!(shards[1 - owner].next_batch(3).is_empty());
        // the owner drains its routed pending plus fresh pulls
        assert_eq!(shards[owner].next_batch(3).len(), 3);
        // eval passes through un-sharded
        assert_eq!(shards[1 - owner].eval_batch(1).len(), 1);
    }

    #[test]
    fn alfworld_source_unique_seeds() {
        let s = AlfworldTaskSource::new(9, 2);
        let b1 = s.next_batch(3);
        let b2 = s.next_batch(3);
        let seeds: Vec<f64> = b1
            .iter()
            .chain(&b2)
            .map(|t| t.payload.get("seed").unwrap().as_f64().unwrap())
            .collect();
        let mut uniq = seeds.clone();
        uniq.sort_by(|a, b| a.partial_cmp(b).unwrap());
        uniq.dedup();
        assert_eq!(uniq.len(), seeds.len());
    }
}
