//! The coordinator: the paper's unified RFT modes (§2.1.1) as sync
//! policies over ONE scheduler, plus typed configuration, run
//! reporting, the monitor, and task sources.

pub mod config;
pub mod modes;
pub mod monitor;
pub mod policy;
pub mod report;
pub mod scheduler;
pub mod tasks;

pub use config::{
    ControlSection, DpoSection, MixSection, ObservabilitySection, OpmdSection, RftConfig,
    SchedulerSection, ServiceSection,
};
pub use monitor::Monitor;
pub use policy::{
    resolve_policy, BoundedStaleness, ExplorerPlan, Free, Offline, Progress, RftMode, SyncPolicy,
    SyncPolicyFactory, SyncPolicyRegistry, Windowed,
};
pub use report::{ModeReport, RolloutRecord, RunRecorder, TimelineEvent};
pub use scheduler::{run_mode, sft_warmup_snapshot, BuildOpts, RftSession};
pub use tasks::{
    AlfworldTaskSource, MathTaskSource, PrioritizedTaskSource, ShardedTaskSource, TaskSource,
};
