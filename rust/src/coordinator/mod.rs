//! The coordinator: the paper's unified RFT modes (§2.1.1) over the
//! explorer / buffer / trainer trinity, plus typed configuration, the
//! monitor, and task sources.

pub mod config;
pub mod modes;
pub mod monitor;
pub mod tasks;

pub use config::{DpoSection, MixSection, OpmdSection, RftConfig};
pub use modes::{run_mode, BuildOpts, ModeReport, RftMode, RftSession};
pub use monitor::Monitor;
pub use tasks::{AlfworldTaskSource, MathTaskSource, PrioritizedTaskSource, TaskSource};
