//! Sync policies: the paper's unified RFT modes (§2.1.1, Fig. 4) as
//! *policy parameterizations of one scheduler*, not separate loops.
//!
//! A [`SyncPolicy`] makes the three coordination decisions the old
//! per-mode loops hard-coded:
//!
//! 1. **Explorer admission** — may an explorer start rollout batch `e`
//!    given the observed run [`Progress`]?
//! 2. **Weight-publish cadence** — does the trainer publish after its
//!    `n`-th completed step?
//! 3. **Shutdown shape** — via [`ExplorerPlan`]: a fixed per-explorer
//!    batch budget (lockstep modes), free-running until the trainer
//!    finishes (async modes), or no explorers at all (offline training).
//!
//! Builtins: [`Windowed`] reproduces `mode=both` (synchronous /
//! one-step off-policy), [`Free`] reproduces `mode=async` including
//! multi-explorer, [`Offline`] reproduces `mode=train`, and
//! [`BoundedStaleness`] is the off-policyness control the UFT line of
//! work motivates: explorers block once the rollout window they would
//! generate leads the published weight version by more than
//! `max_version_lag` windows.  Custom policies register in the
//! [`SyncPolicyRegistry`] and are selected by `scheduler.policy` in
//! config, mirroring the trainer's `AlgorithmRegistry`.

use std::sync::{Arc, OnceLock};

use anyhow::{bail, Result};

use crate::util::Registry;

use super::config::RftConfig;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RftMode {
    /// Synchronous / one-step off-policy (explorer+trainer coordinated).
    Both,
    /// Fully asynchronous (incl. multi-explorer).
    Async,
    /// Trainer alone on an existing buffer (SFT/DPO/offline RL).
    TrainOnly,
    /// Evaluation of current/checkpointed weights.
    Bench,
}

impl RftMode {
    /// Case-insensitive mode lookup.
    pub fn parse(s: &str) -> Result<RftMode> {
        Ok(match s.trim().to_ascii_lowercase().as_str() {
            "both" => RftMode::Both,
            "async" | "explore" => RftMode::Async,
            "train" => RftMode::TrainOnly,
            "bench" => RftMode::Bench,
            _ => bail!("unknown mode '{s}' (valid modes: both, async, explore, train, bench)"),
        })
    }
}

/// The run progress every coordination decision is made against — the
/// scheduler updates one shared copy (in an `exec::WatchCell`) and
/// policies only ever observe it.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Progress {
    /// Completed trainer steps.
    pub trainer_steps: u64,
    /// Completed weight publishes (= the latest published version).
    pub published_windows: u64,
    /// Completed explorer batches, summed over explorers.
    pub explored_batches: u64,
    /// Ready experiences sitting in the buffer (refreshed by both
    /// drivers), so policies can throttle explorers on buffer pressure
    /// instead of relying on blocking writes.
    pub buffer_depth: u64,
}

/// How a policy wants explorer drivers run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExplorerPlan {
    /// No explorer drivers (offline training on a pre-filled buffer).
    None,
    /// Each explorer runs exactly this many batches, then exits.
    Batches(u64),
    /// Explorers free-run until the trainer finishes and cancels the run.
    FreeRun,
}

/// One coordination pattern over the generic scheduler (see module docs).
pub trait SyncPolicy: Send + Sync {
    /// Report label, e.g. `both(i=2,o=0)`.
    fn label(&self, explorer_count: usize) -> String;
    /// Explorer launch/shutdown shape for a run of `total_steps`.
    fn explorer_plan(&self, total_steps: u64) -> ExplorerPlan;
    /// May an explorer start its rollout batch `batch` now?
    fn admit(&self, batch: u64, progress: Progress) -> bool;
    /// Publish weights after `steps_done` completed trainer steps?
    fn publish_after(&self, steps_done: u64) -> bool;
    /// Off-policyness accounting: how many publish-windows the weights
    /// used for `batch` (version `weight_version`) trail the window the
    /// batch belongs to.  0 for policies without a window structure.
    fn version_lag(&self, batch: u64, weight_version: u64) -> u64 {
        let _ = (batch, weight_version);
        0
    }
    /// Whether several explorers may run under this policy (lockstep
    /// admission assumes a single global batch stream).
    fn multi_explorer(&self) -> bool {
        true
    }
    /// Called once at session start when observability is enabled: the
    /// policy may keep the [`TelemetryHub`](crate::obs::TelemetryHub)
    /// and read live service/cache/buffer gauges inside `admit` —
    /// adaptive control beyond the publish-boundary `Progress` counters
    /// (ROADMAP item 2).  The default ignores it.
    fn connect_telemetry(&self, hub: &std::sync::Arc<crate::obs::TelemetryHub>) {
        let _ = hub;
    }
    /// Called once at session start when the `[control]` plane is
    /// enabled: a controller-backed policy (e.g. `"adaptive"`) registers
    /// its controller with the [`ControlPlane`](crate::control::ControlPlane)
    /// so the plane steps it on fresh gauge samples and its decisions
    /// land in the shared log.  The default ignores it, so plain
    /// policies run unchanged under an enabled plane.
    fn connect_control(&self, plane: &std::sync::Arc<crate::control::ControlPlane>) {
        let _ = plane;
    }
}

/// Windowed gating (`mode=both`, Fig. 4 a/b): the explorer may start
/// rollout batch `e` once weight-sync window
/// `floor((e - offset) / interval)` has been published; the trainer
/// publishes every `interval` steps.  `interval=1, offset=0` is the
/// strictly on-policy ping-pong; larger values open the pipeline.
#[derive(Debug, Clone, Copy)]
pub struct Windowed {
    pub interval: u64,
    pub offset: u64,
}

impl SyncPolicy for Windowed {
    fn label(&self, _explorer_count: usize) -> String {
        format!("both(i={},o={})", self.interval, self.offset)
    }
    fn explorer_plan(&self, total_steps: u64) -> ExplorerPlan {
        ExplorerPlan::Batches(total_steps)
    }
    fn admit(&self, batch: u64, progress: Progress) -> bool {
        progress.published_windows >= batch.saturating_sub(self.offset) / self.interval
    }
    fn publish_after(&self, steps_done: u64) -> bool {
        steps_done % self.interval == 0
    }
    fn version_lag(&self, batch: u64, weight_version: u64) -> u64 {
        (batch / self.interval).saturating_sub(weight_version)
    }
    fn multi_explorer(&self) -> bool {
        false
    }
}

/// Free-running (`mode=async`, Fig. 4 c/d): no window gating —
/// explorers run at their own pace and pull weights asynchronously; the
/// trainer publishes every `interval` steps.  `max_buffer > 0` adds
/// buffer-pressure admission: an explorer blocks while the ready buffer
/// depth is at or above the cap, so rollout capacity throttles on
/// consumption lag instead of wedging inside a blocking write
/// (`scheduler.max_buffer_depth`).
#[derive(Debug, Clone, Copy)]
pub struct Free {
    pub interval: u64,
    /// Admission cap on `Progress::buffer_depth`; 0 = uncapped.
    pub max_buffer: u64,
}

impl SyncPolicy for Free {
    fn label(&self, explorer_count: usize) -> String {
        if self.max_buffer > 0 {
            format!("async(i={},buf<{},x{explorer_count})", self.interval, self.max_buffer)
        } else {
            format!("async(i={},x{explorer_count})", self.interval)
        }
    }
    fn explorer_plan(&self, _total_steps: u64) -> ExplorerPlan {
        ExplorerPlan::FreeRun
    }
    fn admit(&self, _batch: u64, progress: Progress) -> bool {
        self.max_buffer == 0 || progress.buffer_depth < self.max_buffer
    }
    fn publish_after(&self, steps_done: u64) -> bool {
        steps_done % self.interval == 0
    }
    // version_lag: trait default (0) — free-running batches are not
    // gated to publish windows, so a window-based lag would measure
    // explorer throughput, not weight staleness
}

/// Offline training (`mode=train`): no explorers, no publishes — the
/// trainer consumes a pre-filled buffer (SFT / DPO / offline RL).
#[derive(Debug, Clone, Copy)]
pub struct Offline;

impl SyncPolicy for Offline {
    fn label(&self, _explorer_count: usize) -> String {
        "train".into()
    }
    fn explorer_plan(&self, _total_steps: u64) -> ExplorerPlan {
        ExplorerPlan::None
    }
    fn admit(&self, _batch: u64, _progress: Progress) -> bool {
        false
    }
    fn publish_after(&self, _steps_done: u64) -> bool {
        false
    }
}

/// Bounded staleness: free-running explorers with a hard off-policyness
/// cap.  Rollout batch `e` belongs to weight window `e / interval`; the
/// explorer may start it only while that window leads the published
/// version by at most `max_version_lag` windows, and blocks otherwise
/// until the trainer publishes.  `max_version_lag = 0` degenerates to
/// windowed on-policy gating (with async shutdown); large values
/// degenerate to [`Free`].
#[derive(Debug, Clone, Copy)]
pub struct BoundedStaleness {
    pub interval: u64,
    pub max_version_lag: u64,
}

impl SyncPolicy for BoundedStaleness {
    fn label(&self, explorer_count: usize) -> String {
        format!("staleness(i={},lag={},x{explorer_count})", self.interval, self.max_version_lag)
    }
    fn explorer_plan(&self, _total_steps: u64) -> ExplorerPlan {
        ExplorerPlan::FreeRun
    }
    fn admit(&self, batch: u64, progress: Progress) -> bool {
        batch / self.interval <= progress.published_windows + self.max_version_lag
    }
    fn publish_after(&self, steps_done: u64) -> bool {
        steps_done % self.interval == 0
    }
    fn version_lag(&self, batch: u64, weight_version: u64) -> u64 {
        (batch / self.interval).saturating_sub(weight_version)
    }
}

// ---------------------------------------------------------------------------
// policy registry

/// Builds a [`SyncPolicy`] from the run config.  Implemented for plain
/// closures, so registration is one line.
pub trait SyncPolicyFactory: Send + Sync {
    fn build(&self, cfg: &RftConfig) -> Result<Arc<dyn SyncPolicy>>;
}

impl<F> SyncPolicyFactory for F
where
    F: Fn(&RftConfig) -> Result<Arc<dyn SyncPolicy>> + Send + Sync,
{
    fn build(&self, cfg: &RftConfig) -> Result<Arc<dyn SyncPolicy>> {
        self(cfg)
    }
}

/// The sync-policy registry (mirrors `AlgorithmRegistry` /
/// `WeightSyncRegistry`): `scheduler.policy` names resolve here.
/// Lookup is case-insensitive; unknown names fail with the catalog.
pub struct SyncPolicyRegistry {
    factories: Registry<Arc<dyn SyncPolicyFactory>>,
}

impl SyncPolicyRegistry {
    /// An empty registry (tests); production code uses [`global`](Self::global).
    pub fn new() -> SyncPolicyRegistry {
        SyncPolicyRegistry {
            factories: Registry::new(
                "sync policy",
                "policies",
                "register custom policies with SyncPolicyRegistry::global().register(..)",
                true,
            ),
        }
    }

    /// A registry pre-populated with the builtin policies and their
    /// mode-name aliases.
    pub fn with_builtins() -> SyncPolicyRegistry {
        let r = SyncPolicyRegistry::new();
        let windowed = |cfg: &RftConfig| -> Result<Arc<dyn SyncPolicy>> {
            Ok(Arc::new(Windowed { interval: cfg.sync_interval, offset: cfg.sync_offset }))
        };
        let free = |cfg: &RftConfig| -> Result<Arc<dyn SyncPolicy>> {
            Ok(Arc::new(Free {
                interval: cfg.sync_interval,
                max_buffer: cfg.scheduler.max_buffer_depth,
            }))
        };
        let offline =
            |_cfg: &RftConfig| -> Result<Arc<dyn SyncPolicy>> { Ok(Arc::new(Offline)) };
        let bounded = |cfg: &RftConfig| -> Result<Arc<dyn SyncPolicy>> {
            Ok(Arc::new(BoundedStaleness {
                interval: cfg.sync_interval,
                max_version_lag: cfg.scheduler.max_version_lag,
            }))
        };
        let adaptive = |cfg: &RftConfig| -> Result<Arc<dyn SyncPolicy>> {
            Ok(Arc::new(crate::control::AdaptiveStaleness::from_cfg(cfg)))
        };
        r.register("windowed", windowed);
        r.register("both", windowed);
        r.register("free", free);
        r.register("async", free);
        r.register("offline", offline);
        r.register("train", offline);
        r.register("bounded_staleness", bounded);
        r.register("staleness", bounded);
        r.register("adaptive", adaptive);
        r
    }

    /// The process-wide registry.  Custom policies register here before
    /// building a session and are selected with `scheduler.policy`:
    ///
    /// ```ignore
    /// SyncPolicyRegistry::global().register("every_other", |cfg: &RftConfig| {
    ///     Ok(Arc::new(Windowed { interval: 2 * cfg.sync_interval, offset: 1 })
    ///         as Arc<dyn SyncPolicy>)
    /// });
    /// ```
    pub fn global() -> &'static SyncPolicyRegistry {
        static GLOBAL: OnceLock<SyncPolicyRegistry> = OnceLock::new();
        GLOBAL.get_or_init(SyncPolicyRegistry::with_builtins)
    }

    /// Register a factory under `name` (stored lowercased; latest wins).
    pub fn register(&self, name: &str, factory: impl SyncPolicyFactory + 'static) {
        self.factories.insert(name, Arc::new(factory));
    }

    pub fn contains(&self, name: &str) -> bool {
        self.factories.contains(name)
    }

    /// Registered policy names (incl. aliases), sorted.
    pub fn names(&self) -> Vec<String> {
        self.factories.names()
    }

    /// Resolve `name` (case-insensitive) and build the policy.
    pub fn build(&self, name: &str, cfg: &RftConfig) -> Result<Arc<dyn SyncPolicy>> {
        self.factories.lookup(name)?.build(cfg)
    }
}

impl Default for SyncPolicyRegistry {
    fn default() -> Self {
        SyncPolicyRegistry::new()
    }
}

/// Resolve the sync policy for a config: an explicit `scheduler.policy`
/// wins; otherwise the `mode` maps onto its builtin policy.
pub fn resolve_policy(cfg: &RftConfig) -> Result<Arc<dyn SyncPolicy>> {
    if let Some(name) = &cfg.scheduler.policy {
        return SyncPolicyRegistry::global().build(name, cfg);
    }
    let name = match RftMode::parse(&cfg.mode)? {
        RftMode::Both => "windowed",
        RftMode::Async => "free",
        RftMode::TrainOnly => "offline",
        RftMode::Bench => bail!("bench mode is not a scheduler run (use run_bench(tiers))"),
    };
    SyncPolicyRegistry::global().build(name, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_parse_is_case_insensitive() {
        assert_eq!(RftMode::parse("both").unwrap(), RftMode::Both);
        assert_eq!(RftMode::parse("BOTH").unwrap(), RftMode::Both);
        assert_eq!(RftMode::parse(" Async ").unwrap(), RftMode::Async);
        assert_eq!(RftMode::parse("Explore").unwrap(), RftMode::Async);
        assert_eq!(RftMode::parse("TRAIN").unwrap(), RftMode::TrainOnly);
        assert_eq!(RftMode::parse("Bench").unwrap(), RftMode::Bench);
    }

    #[test]
    fn mode_parse_error_lists_valid_modes() {
        let err = RftMode::parse("warp").unwrap_err().to_string();
        assert!(err.contains("unknown mode 'warp'"), "{err}");
        for valid in ["both", "async", "explore", "train", "bench"] {
            assert!(err.contains(valid), "error should list '{valid}': {err}");
        }
    }

    fn at(published_windows: u64) -> Progress {
        Progress { published_windows, ..Default::default() }
    }

    #[test]
    fn windowed_interval1_offset0_is_strict_ping_pong() {
        let p = Windowed { interval: 1, offset: 0 };
        // batch e never admitted before window e is published
        for e in 0..20u64 {
            assert!(!p.admit(e + 1, at(e)), "batch {} admitted at {} windows", e + 1, e);
            assert!(p.admit(e, at(e)));
        }
        assert!(p.admit(0, at(0))); // first batch needs nothing
        assert!(p.publish_after(1) && p.publish_after(2)); // publish every step
        assert_eq!(p.explorer_plan(7), ExplorerPlan::Batches(7));
        assert!(!p.multi_explorer());
    }

    #[test]
    fn windowed_offset_and_interval_open_the_pipeline() {
        // one-step off-policy: batch e needs window e-1
        let p = Windowed { interval: 1, offset: 1 };
        assert!(p.admit(1, at(0)) && p.admit(2, at(1)));
        assert!(!p.admit(2, at(0)));
        // interval=2: batches 0..=1 need nothing, 2..=3 need one window
        let p = Windowed { interval: 2, offset: 0 };
        assert!(p.admit(1, at(0)));
        assert!(!p.admit(2, at(0)) && p.admit(3, at(1)));
        assert!(!p.publish_after(1) && p.publish_after(2) && !p.publish_after(3));
    }

    #[test]
    fn free_admits_everything_and_free_runs() {
        let p = Free { interval: 2, max_buffer: 0 };
        for e in 0..100 {
            assert!(p.admit(e, at(0)));
        }
        assert_eq!(p.explorer_plan(5), ExplorerPlan::FreeRun);
        assert!(p.multi_explorer());
        assert!(p.label(2).contains("x2"));
    }

    #[test]
    fn free_throttles_on_buffer_pressure() {
        let p = Free { interval: 1, max_buffer: 8 };
        let shallow = Progress { buffer_depth: 7, ..Default::default() };
        let full = Progress { buffer_depth: 8, ..Default::default() };
        assert!(p.admit(0, shallow));
        assert!(!p.admit(0, full), "at the cap the explorer must block");
        assert!(!p.admit(0, Progress { buffer_depth: 50, ..Default::default() }));
        // draining below the cap re-admits
        assert!(p.admit(1, shallow));
        assert!(p.label(2).contains("buf<8"), "{}", p.label(2));
    }

    #[test]
    fn offline_spawns_no_explorers_and_never_publishes() {
        let p = Offline;
        assert_eq!(p.explorer_plan(9), ExplorerPlan::None);
        assert!(!p.publish_after(1) && !p.publish_after(100));
        assert_eq!(p.label(1), "train");
    }

    #[test]
    fn bounded_staleness_admission_implies_lag_bound() {
        // exhaustive check: whenever a batch is admitted, the window it
        // belongs to leads the published version by at most max_lag —
        // so the post-pull weight-version lag cannot exceed max_lag
        for interval in [1u64, 2, 5] {
            for max_lag in [0u64, 1, 3] {
                let p = BoundedStaleness { interval, max_version_lag: max_lag };
                for batch in 0..60u64 {
                    for published in 0..30u64 {
                        if p.admit(batch, at(published)) {
                            // the explorer pulls before rolling out, so its
                            // version is at least `published`
                            assert!(
                                p.version_lag(batch, published) <= max_lag,
                                "i={interval} lag={max_lag} batch={batch} pub={published}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn bounded_staleness_zero_lag_matches_windowed_gating() {
        let b = BoundedStaleness { interval: 2, max_version_lag: 0 };
        let w = Windowed { interval: 2, offset: 0 };
        for batch in 0..40u64 {
            for published in 0..20u64 {
                assert_eq!(b.admit(batch, at(published)), w.admit(batch, at(published)));
            }
        }
        // but shutdown stays async-shaped
        assert_eq!(b.explorer_plan(5), ExplorerPlan::FreeRun);
    }

    #[test]
    fn bounded_staleness_blocks_then_unblocks_on_publish() {
        let p = BoundedStaleness { interval: 1, max_version_lag: 1 };
        assert!(p.admit(0, at(0)) && p.admit(1, at(0)));
        assert!(!p.admit(2, at(0)), "lead of 2 windows must block at max_lag=1");
        assert!(p.admit(2, at(1)), "a publish lifts the block");
    }

    #[test]
    fn registry_resolves_modes_and_aliases() {
        let cfg = RftConfig { sync_interval: 3, sync_offset: 1, ..Default::default() };
        let reg = SyncPolicyRegistry::global();
        assert_eq!(reg.build("windowed", &cfg).unwrap().label(1), "both(i=3,o=1)");
        assert_eq!(reg.build("BOTH", &cfg).unwrap().label(1), "both(i=3,o=1)");
        assert_eq!(reg.build("Async", &cfg).unwrap().label(2), "async(i=3,x2)");
        assert_eq!(reg.build("train", &cfg).unwrap().label(1), "train");
        assert!(reg.build("Staleness", &cfg).unwrap().label(1).contains("lag=1"));
    }

    #[test]
    fn registry_unknown_policy_lists_catalog() {
        let cfg = RftConfig::default();
        let err = SyncPolicyRegistry::global().build("warp", &cfg).unwrap_err().to_string();
        assert!(err.contains("unknown sync policy 'warp'"), "{err}");
        for name in ["windowed", "free", "offline", "bounded_staleness"] {
            assert!(err.contains(name), "error should list '{name}': {err}");
        }
    }

    #[test]
    fn custom_policy_registers_and_resolves_through_config() {
        SyncPolicyRegistry::global().register(
            "unit_custom_policy",
            |cfg: &RftConfig| -> Result<Arc<dyn SyncPolicy>> {
                Ok(Arc::new(Windowed { interval: cfg.sync_interval * 2, offset: 1 }))
            },
        );
        let mut cfg = RftConfig::default();
        cfg.scheduler.policy = Some("Unit_Custom_Policy".into());
        cfg.sync_interval = 2;
        let p = resolve_policy(&cfg).unwrap();
        assert_eq!(p.label(1), "both(i=4,o=1)");
    }

    #[test]
    fn policy_reads_live_gauges_through_telemetry_hub() {
        use crate::obs::{Gauges, TelemetryHub};
        use std::time::Duration;

        /// Buffer-pressure admission driven by the *live* hub gauge
        /// instead of the publish-boundary `Progress` counter.
        struct HubGated {
            hub: OnceLock<Arc<TelemetryHub>>,
        }
        impl SyncPolicy for HubGated {
            fn label(&self, _n: usize) -> String {
                "hub_gated".into()
            }
            fn explorer_plan(&self, total_steps: u64) -> ExplorerPlan {
                ExplorerPlan::Batches(total_steps)
            }
            fn admit(&self, _batch: u64, _progress: Progress) -> bool {
                match self.hub.get() {
                    Some(hub) => hub.gauges().buffer_depth < 8.0,
                    None => true,
                }
            }
            fn publish_after(&self, _steps_done: u64) -> bool {
                true
            }
            fn connect_telemetry(&self, hub: &Arc<TelemetryHub>) {
                let _ = self.hub.set(Arc::clone(hub));
            }
        }

        let policy = HubGated { hub: OnceLock::new() };
        let hub = Arc::new(TelemetryHub::new(Duration::from_millis(1)));
        assert!(policy.admit(0, Progress::default()), "unconnected policy admits");

        policy.connect_telemetry(&hub);
        hub.publish(Gauges { buffer_depth: 12.0, occupancy: 0.5, ..Default::default() });
        assert!(!policy.admit(0, Progress::default()), "live gauge blocks admission");
        assert_eq!(hub.samples(), 1, "publish counted");
        assert_eq!(hub.gauges().occupancy, 0.5);

        hub.publish(Gauges { buffer_depth: 3.0, ..Default::default() });
        assert!(policy.admit(0, Progress::default()), "drained buffer re-admits");

        // The default trait impl is a no-op: builtins stay gauge-blind.
        let w = Windowed { interval: 1, offset: 0 };
        w.connect_telemetry(&hub);
        assert!(w.admit(0, at(0)));
    }

    #[test]
    fn resolve_policy_maps_modes_and_rejects_bench() {
        let mut cfg = RftConfig::default();
        cfg.mode = "both".into();
        assert!(resolve_policy(&cfg).unwrap().label(1).starts_with("both"));
        cfg.mode = "async".into();
        assert!(resolve_policy(&cfg).unwrap().label(1).starts_with("async"));
        cfg.mode = "train".into();
        assert_eq!(resolve_policy(&cfg).unwrap().label(1), "train");
        cfg.mode = "bench".into();
        assert!(resolve_policy(&cfg).unwrap_err().to_string().contains("run_bench"));
        // explicit policy overrides the mode mapping
        cfg.scheduler.policy = Some("bounded_staleness".into());
        assert!(resolve_policy(&cfg).unwrap().label(1).starts_with("staleness"));
    }
}
