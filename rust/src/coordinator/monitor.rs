//! The monitor (paper §2.4, Fig. 6): per-step metric streams to JSONL +
//! CSV, qualitative rollout-example capture, and console progress — the
//! WandB/TensorBoard stand-in.  JSONL rows carry a `ts` wall-clock
//! field; write failures are counted (and warned about once) instead of
//! silently discarded, and CSV flushes go through a temp-file rename so
//! readers never observe a half-written file.

use std::collections::BTreeMap;
use std::io::Write;
use std::path::PathBuf;
use std::sync::Mutex;
use std::time::{SystemTime, UNIX_EPOCH};

use anyhow::{Context, Result};

use crate::util::json::Value;

struct Inner {
    jsonl: Option<std::fs::File>,
    series: BTreeMap<String, Vec<(u64, f64)>>,
    examples: Vec<(u64, String)>,
    /// JSONL rows lost to write errors (disk full, closed fd, ...).
    dropped: u64,
    /// Whether the one-time drop warning already fired.
    warned: bool,
}

impl Inner {
    /// Write one JSONL row, counting (and warning once about) failures
    /// instead of discarding them.
    fn write_row(&mut self, row: Value) {
        let Some(f) = &mut self.jsonl else { return };
        if writeln!(f, "{}", row.to_string_compact()).is_err() {
            self.dropped += 1;
            if !self.warned {
                self.warned = true;
                crate::log_warn!(
                    "monitor",
                    "metrics.jsonl write failed; further drops counted silently"
                );
            }
        }
    }
}

/// Seconds since the Unix epoch (the `ts` field on every JSONL row).
fn wall_clock_s() -> f64 {
    SystemTime::now().duration_since(UNIX_EPOCH).map(|d| d.as_secs_f64()).unwrap_or(0.0)
}

pub struct Monitor {
    out_dir: Option<PathBuf>,
    inner: Mutex<Inner>,
    pub console_every: u64,
}

impl Monitor {
    /// A monitor writing under `out_dir` (created), or purely in-memory if
    /// `None`.
    pub fn new(out_dir: Option<PathBuf>) -> Result<Monitor> {
        let jsonl = match &out_dir {
            Some(dir) => {
                std::fs::create_dir_all(dir).with_context(|| format!("creating {dir:?}"))?;
                Some(std::fs::File::create(dir.join("metrics.jsonl"))?)
            }
            None => None,
        };
        Ok(Monitor {
            out_dir,
            inner: Mutex::new(Inner {
                jsonl,
                series: BTreeMap::new(),
                examples: vec![],
                dropped: 0,
                warned: false,
            }),
            console_every: 10,
        })
    }

    pub fn in_memory() -> Monitor {
        Self::new(None).unwrap()
    }

    /// Log named scalars under `role` ("trainer", "explorer-0", ...) at a
    /// step.
    pub fn log(&self, role: &str, step: u64, metrics: &[(String, f64)]) {
        let mut inner = self.inner.lock().unwrap();
        for (name, v) in metrics {
            inner.series.entry(format!("{role}/{name}")).or_default().push((step, *v));
        }
        if inner.jsonl.is_some() {
            let mut pairs = vec![
                ("role".to_string(), Value::str(role)),
                ("step".to_string(), Value::num(step as f64)),
                ("ts".to_string(), Value::num(wall_clock_s())),
            ];
            pairs.extend(metrics.iter().map(|(n, v)| (n.clone(), Value::num(*v))));
            inner.write_row(Value::Object(pairs));
        }
        if step % self.console_every == 0 && !metrics.is_empty() {
            let shown: Vec<String> =
                metrics.iter().take(5).map(|(n, v)| format!("{n}={v:.4}")).collect();
            crate::log_info!("monitor", "[{role} step {step}] {}", shown.join(" "));
        }
    }

    /// Capture a qualitative rollout example (paper: concrete trajectories
    /// at different RL steps).
    pub fn log_example(&self, step: u64, text: &str) {
        let mut inner = self.inner.lock().unwrap();
        inner.examples.push((step, text.to_string()));
        if inner.jsonl.is_some() {
            let v = Value::obj(vec![
                ("role", Value::str("example")),
                ("step", Value::num(step as f64)),
                ("ts", Value::num(wall_clock_s())),
                ("text", Value::str(text)),
            ]);
            inner.write_row(v);
        }
    }

    /// Full series for a key (e.g. "trainer/reward").
    pub fn series(&self, key: &str) -> Vec<(u64, f64)> {
        self.inner.lock().unwrap().series.get(key).cloned().unwrap_or_default()
    }

    pub fn series_values(&self, key: &str) -> Vec<f64> {
        self.series(key).into_iter().map(|(_, v)| v).collect()
    }

    pub fn keys(&self) -> Vec<String> {
        self.inner.lock().unwrap().series.keys().cloned().collect()
    }

    pub fn examples(&self) -> Vec<(u64, String)> {
        self.inner.lock().unwrap().examples.clone()
    }

    /// JSONL rows lost to write errors so far.
    pub fn dropped(&self) -> u64 {
        self.inner.lock().unwrap().dropped
    }

    /// Write every series as CSV under the out dir (one file per role).
    /// Each file lands via temp-file + rename, so a concurrent reader
    /// sees either the previous flush or the new one — never a torn
    /// write.
    pub fn flush_csv(&self) -> Result<()> {
        let Some(dir) = &self.out_dir else { return Ok(()) };
        let inner = self.inner.lock().unwrap();
        for (key, points) in &inner.series {
            let fname = format!("{}.csv", key.replace('/', "_"));
            let dest = dir.join(&fname);
            let tmp = dir.join(format!("{fname}.tmp"));
            {
                let mut f = std::fs::File::create(&tmp)
                    .with_context(|| format!("creating {tmp:?}"))?;
                writeln!(f, "step,value")?;
                for (s, v) in points {
                    writeln!(f, "{s},{v}")?;
                }
            }
            std::fs::rename(&tmp, &dest)
                .with_context(|| format!("renaming {tmp:?} -> {dest:?}"))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_accumulate() {
        let m = Monitor::in_memory();
        m.log("trainer", 1, &[("loss".into(), 0.5), ("reward".into(), 0.1)]);
        m.log("trainer", 2, &[("loss".into(), 0.4)]);
        m.log("explorer-0", 1, &[("reward".into(), 0.2)]);
        assert_eq!(m.series("trainer/loss"), vec![(1, 0.5), (2, 0.4)]);
        assert_eq!(m.series_values("explorer-0/reward"), vec![0.2]);
        assert_eq!(m.keys().len(), 3);
        assert_eq!(m.dropped(), 0);
    }

    #[test]
    fn jsonl_and_csv_written() {
        let dir = std::env::temp_dir().join(format!("trft_mon_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let m = Monitor::new(Some(dir.clone())).unwrap();
        m.log("trainer", 1, &[("loss".into(), 1.0)]);
        m.log_example(1, "Q: 1+1 | A: 2");
        m.flush_csv().unwrap();
        let jsonl = std::fs::read_to_string(dir.join("metrics.jsonl")).unwrap();
        assert!(jsonl.lines().count() == 2);
        for line in jsonl.lines() {
            let row = Value::parse(line).unwrap();
            let ts = row.get("ts").and_then(Value::as_f64).unwrap();
            assert!(ts > 1.0e9, "ts should be epoch seconds, got {ts}");
        }
        let csv = std::fs::read_to_string(dir.join("trainer_loss.csv")).unwrap();
        assert!(csv.contains("1,1"));
        assert!(
            !dir.join("trainer_loss.csv.tmp").exists(),
            "temp file must be renamed away"
        );
        assert_eq!(m.dropped(), 0);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn flush_csv_replaces_previous_file_atomically() {
        let dir = std::env::temp_dir().join(format!("trft_mon_atomic_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let m = Monitor::new(Some(dir.clone())).unwrap();
        m.log("trainer", 1, &[("loss".into(), 1.0)]);
        m.flush_csv().unwrap();
        m.log("trainer", 2, &[("loss".into(), 0.5)]);
        m.flush_csv().unwrap();
        let csv = std::fs::read_to_string(dir.join("trainer_loss.csv")).unwrap();
        assert_eq!(csv, "step,value\n1,1\n2,0.5\n");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn write_errors_are_counted_not_swallowed() {
        let m = Monitor::in_memory();
        {
            // simulate a dead sink: a read-only handle fails writeln
            let mut inner = m.inner.lock().unwrap();
            let path = std::env::temp_dir().join(format!("trft_mon_ro_{}", std::process::id()));
            std::fs::write(&path, b"").unwrap();
            inner.jsonl = Some(std::fs::File::open(&path).unwrap());
        }
        m.log("trainer", 1, &[("loss".into(), 1.0)]);
        m.log("trainer", 2, &[("loss".into(), 0.9)]);
        assert_eq!(m.dropped(), 2);
        // series still accumulate in memory despite the dead sink
        assert_eq!(m.series("trainer/loss").len(), 2);
    }

    #[test]
    fn examples_captured() {
        let m = Monitor::in_memory();
        m.log_example(5, "hello");
        assert_eq!(m.examples(), vec![(5, "hello".to_string())]);
    }
}
