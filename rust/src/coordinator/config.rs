//! Typed run configuration (the paper's YAML config files, §3.3/§3.4),
//! parsed from the YAML-subset loader with defaults and validation.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::trainer::{AlgorithmSpec, HyperParams, TauSlot};
use crate::util::json::Value;
use crate::util::yamlite;

use super::policy::{resolve_policy, RftMode};

/// Typed OPMD section (`algorithm.opmd.*`): the mirror-descent
/// temperature, formerly overloaded into the shared tau/beta hyper slot.
#[derive(Debug, Clone)]
pub struct OpmdSection {
    pub tau: f32,
}

impl Default for OpmdSection {
    fn default() -> Self {
        OpmdSection { tau: 1.0 }
    }
}

/// Typed DPO section (`algorithm.dpo.*`).
#[derive(Debug, Clone)]
pub struct DpoSection {
    pub beta: f32,
}

impl Default for DpoSection {
    fn default() -> Self {
        DpoSection { beta: 1.0 }
    }
}

/// Typed MIX section (`algorithm.mix.*`): the SFT weight on expert rows
/// and the expert share of each sampled batch.
#[derive(Debug, Clone)]
pub struct MixSection {
    pub mu: f32,
    pub expert_fraction: f64,
}

impl Default for MixSection {
    fn default() -> Self {
        MixSection { mu: 0.1, expert_fraction: 0.25 }
    }
}

/// Typed scheduler section (`scheduler.*`): explicit sync-policy
/// selection and the bounded-staleness knob.  When `policy` is unset the
/// top-level `mode` maps onto its builtin policy (the seed spelling).
#[derive(Debug, Clone)]
pub struct SchedulerSection {
    /// Sync-policy name resolved through the `SyncPolicyRegistry`
    /// (windowed | free | offline | bounded_staleness | custom).
    pub policy: Option<String>,
    /// `BoundedStaleness`: max publish-windows an explorer's weight
    /// version may trail the rollout window it generates.
    pub max_version_lag: u64,
    /// Keep only the newest N published checkpoints on the sync path;
    /// 0 (the default) keeps everything — rotation is opt-in because
    /// bench-over-checkpoints workflows read intermediate versions.
    /// No-op for non-durable sync methods.
    pub keep_checkpoints: usize,
    /// Hash-partition the task stream across explorers so multi-explorer
    /// runs stop duplicating curriculum order.
    pub shard_tasks: bool,
    /// Buffer-pressure admission cap for free-running policies: an
    /// explorer blocks while the ready buffer depth is at or above this
    /// (0 = uncapped, the seed behavior of blocking writes only).
    pub max_buffer_depth: u64,
}

impl Default for SchedulerSection {
    fn default() -> Self {
        SchedulerSection {
            policy: None,
            max_version_lag: 1,
            keep_checkpoints: 0,
            shard_tasks: true,
            max_buffer_depth: 0,
        }
    }
}

/// Typed rollout-service section (`service.*`): when enabled, explorers
/// share a replica pool behind the in-process rollout service instead of
/// holding direct engine handles (paper §2.2; DESIGN.md §6).
///
/// On by default: the single-replica service is the standard rollout
/// path (rollout output is byte-identical to direct engine handles —
/// see `integration_service.rs`); `enabled: false` opts back into
/// direct handles for runs that need `Explorer::engine`.
#[derive(Debug, Clone)]
pub struct ServiceSection {
    pub enabled: bool,
    /// Engine replicas behind the service.
    pub replicas: usize,
    /// Max rows per shared session (0 = the engine's native batch).
    pub max_batch: usize,
    /// Microbatch admission window, milliseconds.
    pub admission_window_ms: u64,
    /// Tokens sampled between continuous-batching refill checks.
    pub refill_chunk: usize,
    /// Per-request deadline, seconds.
    pub timeout_s: f64,
    /// Attempts per request across replicas (1 = no retry).
    pub max_attempts: usize,
    /// Backoff before a failed request re-routes, milliseconds.
    pub retry_backoff_ms: u64,
    /// Consecutive failures that quarantine a replica.
    pub breaker_failures: usize,
    /// Quarantine cooldown before a health probe, seconds.
    pub quarantine_s: f64,
    /// Prefix-reuse cache for session-tagged multi-turn workflows
    /// (DESIGN.md §7): radix prefix index + parked KV sessions +
    /// affinity routing.
    pub cache_enabled: bool,
    /// Parked KV sessions kept alive per replica (0 = trie/affinity
    /// only, no parking).
    pub cache_max_parked: usize,
    /// Lease TTL on parked sessions, seconds.
    pub cache_ttl_s: f64,
    /// Minimum matched prefix tokens before affinity beats least-loaded.
    pub cache_min_prefix: usize,
    /// Token budget of the prefix trie (0 = unbounded).
    pub cache_trie_tokens: usize,
    /// Load margin within which affinity wins over least-loaded.
    pub cache_overload_margin: usize,
}

impl Default for ServiceSection {
    /// Knob defaults come from `service::ServiceConfig::default()` —
    /// ONE source of truth for YAML-configured and programmatic users.
    fn default() -> Self {
        let d = crate::service::ServiceConfig::default();
        ServiceSection {
            enabled: true,
            replicas: 1,
            max_batch: d.max_batch,
            admission_window_ms: d.admission_window.as_millis() as u64,
            refill_chunk: d.refill_chunk,
            timeout_s: d.request_timeout.as_secs_f64(),
            max_attempts: d.max_attempts,
            retry_backoff_ms: d.retry_backoff.as_millis() as u64,
            breaker_failures: d.breaker_failures as usize,
            quarantine_s: d.quarantine.as_secs_f64(),
            cache_enabled: d.cache.enabled,
            cache_max_parked: d.cache.max_parked,
            cache_ttl_s: d.cache.park_ttl.as_secs_f64(),
            cache_min_prefix: d.cache.min_prefix,
            cache_trie_tokens: d.cache.trie_tokens,
            cache_overload_margin: d.cache.overload_margin,
        }
    }
}

impl ServiceSection {
    /// Bad values survive the conversion (clamped only as far as needed
    /// to avoid `Duration::from_secs_f64` panics on negative/non-finite
    /// or astronomically large inputs) so `ServiceConfig::validate`
    /// rejects them loudly instead of silently correcting the config.
    pub fn to_service_config(&self) -> crate::service::ServiceConfig {
        let secs = |v: f64| {
            let v = if v.is_finite() { v.clamp(0.0, 1e9) } else { 0.0 };
            std::time::Duration::from_secs_f64(v)
        };
        crate::service::ServiceConfig {
            max_batch: self.max_batch,
            admission_window: std::time::Duration::from_millis(self.admission_window_ms),
            refill_chunk: self.refill_chunk,
            request_timeout: secs(self.timeout_s),
            max_attempts: self.max_attempts,
            retry_backoff: std::time::Duration::from_millis(self.retry_backoff_ms),
            breaker_failures: self.breaker_failures.min(u32::MAX as usize) as u32,
            quarantine: secs(self.quarantine_s),
            cache: crate::cache::CacheConfig {
                enabled: self.cache_enabled,
                max_parked: self.cache_max_parked,
                park_ttl: secs(self.cache_ttl_s),
                trie_tokens: self.cache_trie_tokens,
                min_prefix: self.cache_min_prefix,
                overload_margin: self.cache_overload_margin,
            },
            // QoS knobs live in their own `[qos]` section; the session
            // builder overwrites this from `QosSection::to_qos_config`.
            qos: crate::qos::QosConfig::default(),
        }
    }
}

/// Typed QoS section (`qos.*`): request classes, weighted fair
/// scheduling, and live session migration on the rollout service
/// (DESIGN.md §11).  Off by default — when disabled the service
/// dequeues FIFO with the shared deadline and never migrates, and
/// rollouts are byte-identical to the pre-QoS service.
#[derive(Debug, Clone)]
pub struct QosSection {
    pub enabled: bool,
    /// DRR weight per class (backlogged bandwidth share).
    pub train_weight: usize,
    pub eval_weight: usize,
    pub interactive_weight: usize,
    /// Deficit replenished per cursor visit is `weight × quantum` jobs.
    pub quantum: usize,
    /// A queued head older than this pre-empts the deficit order,
    /// milliseconds (0 disables aging).
    pub aging_ms: u64,
    /// Per-class deadline overrides, seconds (0 inherits
    /// `service.timeout_s`).
    pub train_deadline_s: f64,
    pub eval_deadline_s: f64,
    pub interactive_deadline_s: f64,
    /// Per-class queued-job caps the `[control]` admission gate
    /// consults (0 = uncapped).
    pub train_cap: usize,
    pub eval_cap: usize,
    pub interactive_cap: usize,
    /// Migrate parked sessions off overloaded/quarantined holders.
    pub migration: bool,
    /// Minimum prefill tokens a migration must save to be attempted.
    pub migrate_min_tokens: usize,
}

impl Default for QosSection {
    /// Knob defaults come from `qos::QosConfig::default()` — one source
    /// of truth for YAML-configured and programmatic users.
    fn default() -> Self {
        use crate::qos::RequestClass;
        let d = crate::qos::QosConfig::default();
        QosSection {
            enabled: d.enabled,
            train_weight: d.weights[RequestClass::TrainRollout.index()] as usize,
            eval_weight: d.weights[RequestClass::Eval.index()] as usize,
            interactive_weight: d.weights[RequestClass::Interactive.index()] as usize,
            quantum: d.quantum as usize,
            aging_ms: d.aging.as_millis() as u64,
            train_deadline_s: d.deadlines[RequestClass::TrainRollout.index()].as_secs_f64(),
            eval_deadline_s: d.deadlines[RequestClass::Eval.index()].as_secs_f64(),
            interactive_deadline_s: d.deadlines[RequestClass::Interactive.index()].as_secs_f64(),
            train_cap: d.class_caps[RequestClass::TrainRollout.index()],
            eval_cap: d.class_caps[RequestClass::Eval.index()],
            interactive_cap: d.class_caps[RequestClass::Interactive.index()],
            migration: d.migration,
            migrate_min_tokens: d.migrate_min_tokens,
        }
    }
}

impl QosSection {
    /// Bad values survive the conversion (clamped only as far as needed
    /// to avoid `Duration::from_secs_f64` panics) so `QosConfig::validate`
    /// rejects them loudly instead of silently correcting the config.
    pub fn to_qos_config(&self) -> crate::qos::QosConfig {
        let secs = |v: f64| {
            let v = if v.is_finite() { v.clamp(0.0, 1e9) } else { 0.0 };
            std::time::Duration::from_secs_f64(v)
        };
        let w = |v: usize| v.min(u32::MAX as usize) as u32;
        crate::qos::QosConfig {
            enabled: self.enabled,
            weights: [w(self.train_weight), w(self.eval_weight), w(self.interactive_weight)],
            quantum: w(self.quantum),
            aging: std::time::Duration::from_millis(self.aging_ms),
            deadlines: [
                secs(self.train_deadline_s),
                secs(self.eval_deadline_s),
                secs(self.interactive_deadline_s),
            ],
            class_caps: [self.train_cap, self.eval_cap, self.interactive_cap],
            migration: self.migration,
            migrate_min_tokens: self.migrate_min_tokens,
        }
    }
}

/// Typed observability section (`observability.*`): the tracing and
/// metrics plane (DESIGN.md §8).  Off by default — when disabled no
/// recorder or telemetry hub is built and runs behave byte-identically.
#[derive(Debug, Clone)]
pub struct ObservabilitySection {
    pub enabled: bool,
    /// Span ring capacity (rounded up to a power of two).
    pub ring_capacity: usize,
    /// Telemetry-hub sampling cadence, seconds.
    pub sample_every_s: f64,
    /// Where `trace.json` is written (default: the monitor dir).
    pub trace_path: Option<String>,
    /// Gauge snapshots retained for trend windows (0 = no history).
    pub gauge_history: usize,
    /// Slowest episodes reported with critical-path breakdowns.
    pub critical_top_k: usize,
    /// Flight-recorder dump cap over the run (0 disables dumping).
    pub flight_max_dumps: u64,
    /// Minimum spacing between flight dumps, seconds.
    pub flight_min_interval_s: f64,
    /// Deadline expiries within the window that count as a burst
    /// (0 disables the deadline-burst trigger).
    pub flight_expiry_burst: usize,
    /// Window for the expiry-burst counter, seconds.
    pub flight_expiry_window_s: f64,
    /// Newest spans embedded per flight dump.
    pub flight_span_tail: usize,
    /// SLO burn rate that fires a flight dump (0 disables).
    pub flight_burn_threshold: f64,
    /// Per-class SLO latency targets, seconds (0 = class untracked).
    pub slo_train_s: f64,
    pub slo_eval_s: f64,
    pub slo_interactive_s: f64,
    /// Fraction of waits that must meet the target (error budget is
    /// `1 - objective`).
    pub slo_objective: f64,
}

impl Default for ObservabilitySection {
    /// Knob defaults come from `obs::ObsConfig::default()` — one source
    /// of truth for YAML-configured and programmatic users.
    fn default() -> Self {
        let d = crate::obs::ObsConfig::default();
        ObservabilitySection {
            enabled: d.enabled,
            ring_capacity: d.ring_capacity,
            sample_every_s: d.sample_every.as_secs_f64(),
            trace_path: None,
            gauge_history: d.gauge_history,
            critical_top_k: d.critical_top_k,
            flight_max_dumps: d.flight.max_dumps,
            flight_min_interval_s: d.flight.min_interval.as_secs_f64(),
            flight_expiry_burst: d.flight.expiry_burst as usize,
            flight_expiry_window_s: d.flight.expiry_window.as_secs_f64(),
            flight_span_tail: d.flight.span_tail,
            flight_burn_threshold: d.flight.burn_threshold,
            slo_train_s: 0.0,
            slo_eval_s: 0.0,
            slo_interactive_s: 0.0,
            slo_objective: d.slo.objective,
        }
    }
}

impl ObservabilitySection {
    /// Clamped only as far as needed to avoid `Duration::from_secs_f64`
    /// panics; `ObsConfig::validate` rejects bad values loudly.
    /// `flight.dir` stays `None` here — the session build fills it from
    /// the monitor dir.
    pub fn to_obs_config(&self) -> crate::obs::ObsConfig {
        let secs = |v: f64| {
            let v = if v.is_finite() { v.clamp(0.0, 1e9) } else { 0.0 };
            std::time::Duration::from_secs_f64(v)
        };
        crate::obs::ObsConfig {
            enabled: self.enabled,
            ring_capacity: self.ring_capacity,
            sample_every: secs(self.sample_every_s),
            trace_path: self.trace_path.as_ref().map(PathBuf::from),
            gauge_history: self.gauge_history,
            critical_top_k: self.critical_top_k,
            flight: crate::obs::FlightConfig {
                dir: None,
                max_dumps: self.flight_max_dumps,
                min_interval: secs(self.flight_min_interval_s),
                expiry_burst: self.flight_expiry_burst.min(u32::MAX as usize) as u32,
                expiry_window: secs(self.flight_expiry_window_s),
                span_tail: self.flight_span_tail,
                burn_threshold: self.flight_burn_threshold,
            },
            slo: crate::obs::SloConfig {
                targets: [
                    secs(self.slo_train_s),
                    secs(self.slo_eval_s),
                    secs(self.slo_interactive_s),
                ],
                objective: self.slo_objective,
            },
        }
    }
}

/// Typed control section (`control.*`): the adaptive control plane over
/// the telemetry gauges (DESIGN.md §9).  Off by default — when disabled
/// no `ControlPlane` is built and scheduling is byte-identical.
#[derive(Debug, Clone)]
pub struct ControlSection {
    pub enabled: bool,
    /// Hold controller outputs when the latest gauge sample is older.
    pub max_gauge_age_s: f64,
    /// Decisions retained for the run report.
    pub log_capacity: usize,
    /// Consecutive out-of-band samples before any output moves.
    pub hold_ticks: u64,
    /// Widen staleness above this fraction of rollout p95.
    pub staleness_hi: f64,
    /// Narrow staleness below this fraction of rollout p95.
    pub staleness_lo: f64,
    /// Sample waits under this are noise, never starvation (seconds).
    pub staleness_floor_s: f64,
    /// Queue-wait p95 mapping to admission pressure 1.0 (seconds).
    pub wait_hi_s: f64,
    /// Queued requests per healthy replica mapping to pressure 1.0.
    pub queue_hi: f64,
    /// Quarantined pool fraction mapping to pressure 1.0.
    pub quarantine_hi: f64,
    /// Pressure at which a closed admission gate reopens.
    pub release: f64,
    /// Rows of headroom (× live capacity) the capacity controller targets.
    pub capacity_headroom: f64,
    /// Lower clamp for per-driver batch tasks.
    pub min_batch_tasks: usize,
    /// Upper clamp for per-driver batch tasks (0 = configured `batch_tasks`).
    pub max_batch_tasks: usize,
}

impl Default for ControlSection {
    /// Knob defaults come from `control::ControlConfig::default()` — one
    /// source of truth for YAML-configured and programmatic users.
    fn default() -> Self {
        let d = crate::control::ControlConfig::default();
        ControlSection {
            enabled: d.enabled,
            max_gauge_age_s: d.max_gauge_age_s,
            log_capacity: d.log_capacity,
            hold_ticks: d.hold_ticks,
            staleness_hi: d.staleness_hi,
            staleness_lo: d.staleness_lo,
            staleness_floor_s: d.staleness_floor_s,
            wait_hi_s: d.wait_hi_s,
            queue_hi: d.queue_hi,
            quarantine_hi: d.quarantine_hi,
            release: d.release,
            capacity_headroom: d.capacity_headroom,
            min_batch_tasks: d.min_batch_tasks,
            max_batch_tasks: d.max_batch_tasks,
        }
    }
}

impl ControlSection {
    /// Bad values survive the conversion so `ControlConfig::validate`
    /// rejects them loudly instead of silently correcting the config.
    pub fn to_control_config(&self) -> crate::control::ControlConfig {
        crate::control::ControlConfig {
            enabled: self.enabled,
            max_gauge_age_s: self.max_gauge_age_s,
            log_capacity: self.log_capacity,
            hold_ticks: self.hold_ticks,
            staleness_hi: self.staleness_hi,
            staleness_lo: self.staleness_lo,
            staleness_floor_s: self.staleness_floor_s,
            wait_hi_s: self.wait_hi_s,
            queue_hi: self.queue_hi,
            quarantine_hi: self.quarantine_hi,
            release: self.release,
            capacity_headroom: self.capacity_headroom,
            min_batch_tasks: self.min_batch_tasks,
            max_batch_tasks: self.max_batch_tasks,
        }
    }
}

#[derive(Debug, Clone)]
pub struct RftConfig {
    /// both | async | explore | train | bench
    pub mode: String,
    /// Typed scheduler/staleness keys (see [`SchedulerSection`]).
    pub scheduler: SchedulerSection,
    /// Typed rollout-service keys (see [`ServiceSection`]).
    pub service: ServiceSection,
    /// Typed observability keys (see [`ObservabilitySection`]).
    pub observability: ObservabilitySection,
    /// Typed control-plane keys (see [`ControlSection`]).
    pub control: ControlSection,
    /// Typed QoS serving-plane keys (see [`QosSection`]).
    pub qos: QosSection,
    pub model_preset: String,
    pub seed: u64,
    /// Registered algorithm name (see `trinity algorithms list`).
    pub algorithm: String,
    /// Base optimizer/loss hypers.  The tau/beta and mu ABI slots are
    /// filled from the typed sections below by [`RftConfig::effective_hyper`].
    pub hyper: HyperParams,
    pub opmd: OpmdSection,
    pub dpo: DpoSection,
    pub mix: MixSection,
    pub adv_std_normalize: bool,
    /// Dummy learning: force lr = 0 (Tables 1-2 profiling).
    pub dummy_learning: bool,

    pub total_steps: u64,
    pub sync_interval: u64,
    pub sync_offset: u64,
    /// Number of independent explorers (multi-explorer async mode).
    pub explorer_count: usize,
    pub explorer_threads: usize,
    /// Tasks per explorer batch (each task yields `repeat_times` rollouts).
    pub batch_tasks: usize,
    pub repeat_times: usize,

    pub temperature: f32,
    pub top_k: usize,
    pub top_p: f32,
    pub max_new_tokens: usize,

    /// queue | file
    pub buffer_kind: String,
    pub buffer_capacity: usize,
    pub buffer_path: Option<PathBuf>,
    /// memory | checkpoint
    pub sync_method: String,
    pub sync_dir: Option<PathBuf>,

    /// Workflow + task source ("math" or "alfworld").
    pub workflow: String,
    pub min_difficulty: usize,
    pub max_difficulty: usize,

    pub task_timeout_s: f64,
    pub task_max_attempts: usize,

    /// Evaluate (and snapshot) every N train steps; 0 = never.
    pub eval_every: u64,
    pub eval_tasks: usize,

    pub monitor_dir: Option<PathBuf>,
    pub artifacts_dir: Option<PathBuf>,
}

impl Default for RftConfig {
    fn default() -> Self {
        RftConfig {
            mode: "both".into(),
            scheduler: SchedulerSection::default(),
            service: ServiceSection::default(),
            observability: ObservabilitySection::default(),
            control: ControlSection::default(),
            qos: QosSection::default(),
            model_preset: "tiny".into(),
            seed: 42,
            algorithm: "grpo".into(),
            hyper: HyperParams::default(),
            opmd: OpmdSection::default(),
            dpo: DpoSection::default(),
            mix: MixSection::default(),
            adv_std_normalize: false,
            dummy_learning: false,
            total_steps: 10,
            sync_interval: 1,
            sync_offset: 0,
            explorer_count: 1,
            explorer_threads: 2,
            batch_tasks: 1,
            repeat_times: 4,
            temperature: 1.0,
            top_k: 0,
            top_p: 1.0,
            max_new_tokens: 8,
            buffer_kind: "queue".into(),
            buffer_capacity: 4096,
            buffer_path: None,
            sync_method: "memory".into(),
            sync_dir: None,
            workflow: "math".into(),
            min_difficulty: 1,
            max_difficulty: 2,
            task_timeout_s: 300.0,
            task_max_attempts: 2,
            eval_every: 0,
            eval_tasks: 16,
            monitor_dir: None,
            artifacts_dir: None,
        }
    }
}

impl RftConfig {
    pub fn from_file(path: impl AsRef<Path>) -> Result<RftConfig> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading config {:?}", path.as_ref()))?;
        let v = yamlite::parse(&text).context("parsing config yaml")?;
        Self::from_value(&v)
    }

    pub fn from_value(v: &Value) -> Result<RftConfig> {
        let mut cfg = RftConfig::default();
        let s = |key: &str, out: &mut String| {
            if let Some(x) = v.path(key).and_then(Value::as_str) {
                *out = x.to_string();
            }
        };
        let u = |key: &str, out: &mut u64| {
            if let Some(x) = v.path(key).and_then(Value::as_i64) {
                *out = x.max(0) as u64;
            }
        };
        let us = |key: &str, out: &mut usize| {
            if let Some(x) = v.path(key).and_then(Value::as_usize) {
                *out = x;
            }
        };
        let f = |key: &str, out: &mut f32| {
            if let Some(x) = v.path(key).and_then(Value::as_f64) {
                *out = x as f32;
            }
        };
        let b = |key: &str, out: &mut bool| {
            if let Some(x) = v.path(key).and_then(Value::as_bool) {
                *out = x;
            }
        };

        s("mode", &mut cfg.mode);
        s("model.preset", &mut cfg.model_preset);
        u("model.seed", &mut cfg.seed);
        s("algorithm.name", &mut cfg.algorithm);
        f("algorithm.lr", &mut cfg.hyper.lr);
        f("algorithm.clip_eps", &mut cfg.hyper.clip_eps);
        f("algorithm.kl_coef", &mut cfg.hyper.kl_coef);
        // back-compat first: the seed's flat keys that overloaded the
        // shared tau/beta and mu hyper slots still parse into the typed
        // sections (and into the raw slot, for custom algorithms that
        // declare TauSlot::Unused) — the typed sections below take
        // precedence when both spellings are present
        f("algorithm.tau", &mut cfg.opmd.tau);
        f("algorithm.tau", &mut cfg.hyper.tau_or_beta);
        f("algorithm.beta", &mut cfg.dpo.beta);
        f("algorithm.beta", &mut cfg.hyper.tau_or_beta);
        f("algorithm.mu", &mut cfg.mix.mu);
        // typed per-algorithm sections
        f("algorithm.opmd.tau", &mut cfg.opmd.tau);
        f("algorithm.dpo.beta", &mut cfg.dpo.beta);
        f("algorithm.mix.mu", &mut cfg.mix.mu);
        if let Some(x) = v.path("algorithm.mix.expert_fraction").and_then(Value::as_f64) {
            cfg.mix.expert_fraction = x;
        }
        b("algorithm.adv_std_normalize", &mut cfg.adv_std_normalize);
        b("algorithm.dummy_learning", &mut cfg.dummy_learning);

        u("train.total_steps", &mut cfg.total_steps);
        // back-compat first: the seed's flat `mode` (above) and
        // `sync.interval` / `sync.offset` keys still parse; the typed
        // `[scheduler]` section below wins when both are present
        u("sync.interval", &mut cfg.sync_interval);
        u("sync.offset", &mut cfg.sync_offset);
        s("sync.method", &mut cfg.sync_method);
        if let Some(d) = v.path("sync.dir").and_then(Value::as_str) {
            cfg.sync_dir = Some(PathBuf::from(d));
        }
        // typed scheduler section
        if let Some(p) = v.path("scheduler.policy").and_then(Value::as_str) {
            cfg.scheduler.policy = Some(p.to_string());
        }
        u("scheduler.interval", &mut cfg.sync_interval);
        u("scheduler.offset", &mut cfg.sync_offset);
        u("scheduler.max_version_lag", &mut cfg.scheduler.max_version_lag);
        us("scheduler.keep_checkpoints", &mut cfg.scheduler.keep_checkpoints);
        b("scheduler.shard_tasks", &mut cfg.scheduler.shard_tasks);
        u("scheduler.max_buffer_depth", &mut cfg.scheduler.max_buffer_depth);

        // typed rollout-service section
        b("service.enabled", &mut cfg.service.enabled);
        us("service.replicas", &mut cfg.service.replicas);
        us("service.max_batch", &mut cfg.service.max_batch);
        u("service.admission_window_ms", &mut cfg.service.admission_window_ms);
        us("service.refill_chunk", &mut cfg.service.refill_chunk);
        if let Some(x) = v.path("service.timeout_s").and_then(Value::as_f64) {
            cfg.service.timeout_s = x;
        }
        us("service.max_attempts", &mut cfg.service.max_attempts);
        u("service.retry_backoff_ms", &mut cfg.service.retry_backoff_ms);
        us("service.breaker_failures", &mut cfg.service.breaker_failures);
        if let Some(x) = v.path("service.quarantine_s").and_then(Value::as_f64) {
            cfg.service.quarantine_s = x;
        }
        b("service.cache_enabled", &mut cfg.service.cache_enabled);
        us("service.cache_max_parked", &mut cfg.service.cache_max_parked);
        if let Some(x) = v.path("service.cache_ttl_s").and_then(Value::as_f64) {
            cfg.service.cache_ttl_s = x;
        }
        us("service.cache_min_prefix", &mut cfg.service.cache_min_prefix);
        us("service.cache_trie_tokens", &mut cfg.service.cache_trie_tokens);
        us("service.cache_overload_margin", &mut cfg.service.cache_overload_margin);

        // typed observability section
        b("observability.enabled", &mut cfg.observability.enabled);
        us("observability.ring_capacity", &mut cfg.observability.ring_capacity);
        if let Some(p) = v.path("observability.trace_path").and_then(Value::as_str) {
            cfg.observability.trace_path = Some(p.to_string());
        }
        us("observability.gauge_history", &mut cfg.observability.gauge_history);
        us("observability.critical_top_k", &mut cfg.observability.critical_top_k);
        u("observability.flight_max_dumps", &mut cfg.observability.flight_max_dumps);
        us("observability.flight_expiry_burst", &mut cfg.observability.flight_expiry_burst);
        us("observability.flight_span_tail", &mut cfg.observability.flight_span_tail);
        {
            let g = |key: &str, out: &mut f64| {
                if let Some(x) = v.path(key).and_then(Value::as_f64) {
                    *out = x;
                }
            };
            g("observability.sample_every_s", &mut cfg.observability.sample_every_s);
            g("observability.flight_min_interval_s", &mut cfg.observability.flight_min_interval_s);
            g("observability.flight_expiry_window_s", &mut cfg.observability.flight_expiry_window_s);
            g("observability.flight_burn_threshold", &mut cfg.observability.flight_burn_threshold);
            g("observability.slo_train_s", &mut cfg.observability.slo_train_s);
            g("observability.slo_eval_s", &mut cfg.observability.slo_eval_s);
            g("observability.slo_interactive_s", &mut cfg.observability.slo_interactive_s);
            g("observability.slo_objective", &mut cfg.observability.slo_objective);
        }

        // typed control-plane section
        b("control.enabled", &mut cfg.control.enabled);
        us("control.log_capacity", &mut cfg.control.log_capacity);
        u("control.hold_ticks", &mut cfg.control.hold_ticks);
        us("control.min_batch_tasks", &mut cfg.control.min_batch_tasks);
        us("control.max_batch_tasks", &mut cfg.control.max_batch_tasks);
        {
            let g = |key: &str, out: &mut f64| {
                if let Some(x) = v.path(key).and_then(Value::as_f64) {
                    *out = x;
                }
            };
            g("control.max_gauge_age_s", &mut cfg.control.max_gauge_age_s);
            g("control.staleness_hi", &mut cfg.control.staleness_hi);
            g("control.staleness_lo", &mut cfg.control.staleness_lo);
            g("control.staleness_floor_s", &mut cfg.control.staleness_floor_s);
            g("control.wait_hi_s", &mut cfg.control.wait_hi_s);
            g("control.queue_hi", &mut cfg.control.queue_hi);
            g("control.quarantine_hi", &mut cfg.control.quarantine_hi);
            g("control.release", &mut cfg.control.release);
            g("control.capacity_headroom", &mut cfg.control.capacity_headroom);
        }

        // typed QoS serving-plane section
        b("qos.enabled", &mut cfg.qos.enabled);
        us("qos.train_weight", &mut cfg.qos.train_weight);
        us("qos.eval_weight", &mut cfg.qos.eval_weight);
        us("qos.interactive_weight", &mut cfg.qos.interactive_weight);
        us("qos.quantum", &mut cfg.qos.quantum);
        u("qos.aging_ms", &mut cfg.qos.aging_ms);
        {
            let g = |key: &str, out: &mut f64| {
                if let Some(x) = v.path(key).and_then(Value::as_f64) {
                    *out = x;
                }
            };
            g("qos.train_deadline_s", &mut cfg.qos.train_deadline_s);
            g("qos.eval_deadline_s", &mut cfg.qos.eval_deadline_s);
            g("qos.interactive_deadline_s", &mut cfg.qos.interactive_deadline_s);
        }
        us("qos.train_cap", &mut cfg.qos.train_cap);
        us("qos.eval_cap", &mut cfg.qos.eval_cap);
        us("qos.interactive_cap", &mut cfg.qos.interactive_cap);
        b("qos.migration", &mut cfg.qos.migration);
        us("qos.migrate_min_tokens", &mut cfg.qos.migrate_min_tokens);

        us("explorer.count", &mut cfg.explorer_count);
        us("explorer.threads", &mut cfg.explorer_threads);
        us("explorer.batch_tasks", &mut cfg.batch_tasks);
        us("explorer.repeat_times", &mut cfg.repeat_times);
        f("explorer.temperature", &mut cfg.temperature);
        us("explorer.top_k", &mut cfg.top_k);
        f("explorer.top_p", &mut cfg.top_p);
        us("explorer.max_new_tokens", &mut cfg.max_new_tokens);
        if let Some(x) = v.path("explorer.timeout_s").and_then(Value::as_f64) {
            cfg.task_timeout_s = x;
        }
        us("explorer.max_attempts", &mut cfg.task_max_attempts);

        s("buffer.kind", &mut cfg.buffer_kind);
        us("buffer.capacity", &mut cfg.buffer_capacity);
        if let Some(p) = v.path("buffer.path").and_then(Value::as_str) {
            cfg.buffer_path = Some(PathBuf::from(p));
        }

        s("data.workflow", &mut cfg.workflow);
        us("data.min_difficulty", &mut cfg.min_difficulty);
        us("data.max_difficulty", &mut cfg.max_difficulty);

        u("eval.every", &mut cfg.eval_every);
        us("eval.tasks", &mut cfg.eval_tasks);
        if let Some(d) = v.path("monitor.dir").and_then(Value::as_str) {
            cfg.monitor_dir = Some(PathBuf::from(d));
        }
        if let Some(d) = v.path("artifacts.dir").and_then(Value::as_str) {
            cfg.artifacts_dir = Some(PathBuf::from(d));
        }

        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        // case-insensitive, lists valid modes on error
        let mode = RftMode::parse(&self.mode)?;
        if self.sync_interval == 0 {
            bail!("sync.interval must be >= 1");
        }
        if self.explorer_count == 0 {
            bail!("explorer.count must be >= 1");
        }
        // resolve the sync policy now so bad `scheduler.policy` names
        // fail at config time with the registry catalog; bench-mode
        // sessions without an explicit policy never reach the scheduler
        if mode != RftMode::Bench || self.scheduler.policy.is_some() {
            let policy = resolve_policy(self)?;
            if self.explorer_count > 1 && !policy.multi_explorer() {
                bail!(
                    "multi-explorer requires a free-running sync policy \
                     (mode=async or scheduler.policy=free/bounded_staleness; paper §2.1.1)"
                );
            }
        }
        match self.workflow.as_str() {
            "math" | "alfworld" | "reflect_once" => {}
            other => bail!("unknown workflow '{other}'"),
        }
        if self.service.enabled {
            if self.service.replicas == 0 {
                bail!("service.replicas must be >= 1");
            }
            if !self.service.timeout_s.is_finite()
                || !self.service.quarantine_s.is_finite()
                || !self.service.cache_ttl_s.is_finite()
            {
                bail!("service timeout_s / quarantine_s / cache_ttl_s must be finite");
            }
            // surface bad knobs at config time, not at session build
            self.service.to_service_config().validate()?;
        }
        if self.observability.enabled {
            if !self.observability.sample_every_s.is_finite() {
                bail!("observability.sample_every_s must be finite");
            }
            self.observability.to_obs_config().validate()?;
        }
        // no-op when [control] is absent/disabled
        self.control.to_control_config().validate()?;
        // no-op when [qos] is absent/disabled
        self.qos.to_qos_config().validate()?;
        Ok(())
    }

    /// Effective hyper-parameters for a resolved algorithm spec: the
    /// typed per-algorithm sections fill the ABI slots the old config
    /// overloaded (tau/beta via the spec's [`TauSlot`], mu from the MIX
    /// section), and dummy learning zeroes the lr, keeping all compute
    /// identical (the paper's profiling methodology).
    pub fn effective_hyper(&self, spec: &AlgorithmSpec) -> HyperParams {
        let mut h = self.hyper.clone();
        h.tau_or_beta = match spec.loss.tau_slot {
            TauSlot::OpmdTau => self.opmd.tau,
            TauSlot::DpoBeta => self.dpo.beta,
            TauSlot::Unused => h.tau_or_beta,
        };
        h.mu = self.mix.mu;
        if self.dummy_learning {
            h.lr = 0.0;
        }
        h
    }

    /// Short stable digest of the full resolved config (FNV-1a over the
    /// `Debug` form): stamps flight dumps and reports so a post-mortem
    /// can tell which configuration produced them.  Identical configs
    /// digest identically; any knob change moves it.
    pub fn digest(&self) -> String {
        let mut hash: u64 = 0xcbf2_9ce4_8422_2325;
        for byte in format!("{self:?}").bytes() {
            hash ^= byte as u64;
            hash = hash.wrapping_mul(0x0000_0100_0000_01b3);
        }
        format!("{hash:016x}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
mode: both
model:
  preset: tiny
  seed: 7
algorithm:
  name: grpo
  lr: 0.0005
  clip_eps: 0.3
  dummy_learning: true
train:
  total_steps: 25
sync:
  interval: 10
  offset: 1
explorer:
  count: 1
  threads: 4
  batch_tasks: 2
  repeat_times: 4
  temperature: 0.8
buffer:
  kind: queue
  capacity: 128
data:
  workflow: math
  min_difficulty: 1
  max_difficulty: 3
eval:
  every: 5
  tasks: 8
";

    #[test]
    fn parses_full_config() {
        let v = yamlite::parse(SAMPLE).unwrap();
        let cfg = RftConfig::from_value(&v).unwrap();
        assert_eq!(cfg.mode, "both");
        assert_eq!(cfg.seed, 7);
        assert!((cfg.hyper.lr - 5e-4).abs() < 1e-9);
        assert!((cfg.hyper.clip_eps - 0.3).abs() < 1e-9);
        assert_eq!(cfg.total_steps, 25);
        assert_eq!(cfg.sync_interval, 10);
        assert_eq!(cfg.sync_offset, 1);
        assert_eq!(cfg.explorer_threads, 4);
        assert_eq!(cfg.eval_every, 5);
        assert!(cfg.dummy_learning);
        let spec = crate::trainer::AlgorithmRegistry::global().get(&cfg.algorithm).unwrap();
        assert_eq!(cfg.effective_hyper(&spec).lr, 0.0);
    }

    #[test]
    fn defaults_fill_missing() {
        let cfg = RftConfig::from_value(&yamlite::parse("mode: both\n").unwrap()).unwrap();
        assert_eq!(cfg.model_preset, "tiny");
        assert_eq!(cfg.sync_interval, 1);
    }

    #[test]
    fn mode_parse_is_case_insensitive() {
        let cfg = RftConfig::from_value(&yamlite::parse("mode: BOTH\n").unwrap()).unwrap();
        assert_eq!(cfg.mode, "BOTH"); // preserved verbatim, parsed case-insensitively
        assert!(RftConfig::from_value(&yamlite::parse("mode: Train\n").unwrap()).is_ok());
    }

    #[test]
    fn typed_sections_fill_abi_slots_per_spec() {
        let yaml = "\
mode: train
algorithm:
  name: opmd_kimi
  opmd:
    tau: 0.7
  dpo:
    beta: 0.3
  mix:
    mu: 0.4
    expert_fraction: 0.5
";
        let cfg = RftConfig::from_value(&yamlite::parse(yaml).unwrap()).unwrap();
        assert!((cfg.opmd.tau - 0.7).abs() < 1e-6);
        assert!((cfg.dpo.beta - 0.3).abs() < 1e-6);
        assert!((cfg.mix.expert_fraction - 0.5).abs() < 1e-9);
        let reg = crate::trainer::AlgorithmRegistry::global();
        // the tau/beta slot is routed by the spec's TauSlot declaration
        let h = cfg.effective_hyper(&reg.get("opmd_kimi").unwrap());
        assert!((h.tau_or_beta - 0.7).abs() < 1e-6);
        let h = cfg.effective_hyper(&reg.get("dpo").unwrap());
        assert!((h.tau_or_beta - 0.3).abs() < 1e-6);
        assert!((h.mu - 0.4).abs() < 1e-6);
    }

    #[test]
    fn old_overloaded_keys_still_parse() {
        // the seed's flat keys map into the typed sections
        let yaml = "mode: train\nalgorithm:\n  name: dpo\n  beta: 0.5\n  tau: 2.0\n  mu: 0.3\n";
        let cfg = RftConfig::from_value(&yamlite::parse(yaml).unwrap()).unwrap();
        assert!((cfg.dpo.beta - 0.5).abs() < 1e-6);
        assert!((cfg.opmd.tau - 2.0).abs() < 1e-6);
        assert!((cfg.mix.mu - 0.3).abs() < 1e-6);
        let reg = crate::trainer::AlgorithmRegistry::global();
        assert!((cfg.effective_hyper(&reg.get("dpo").unwrap()).tau_or_beta - 0.5).abs() < 1e-6);
        assert!((cfg.effective_hyper(&reg.get("opmd_simple").unwrap()).tau_or_beta - 2.0).abs() < 1e-6);
    }

    #[test]
    fn rejects_bad_configs() {
        assert!(RftConfig::from_value(&yamlite::parse("mode: warp\n").unwrap()).is_err());
        assert!(RftConfig::from_value(&yamlite::parse("mode: both\nsync:\n  interval: 0\n").unwrap())
            .is_err());
        assert!(RftConfig::from_value(
            &yamlite::parse("mode: both\nexplorer:\n  count: 2\n").unwrap()
        )
        .is_err());
        // the multi-explorer guard applies to the parsed mode, so case
        // variants cannot sneak past it
        assert!(RftConfig::from_value(
            &yamlite::parse("mode: BOTH\nexplorer:\n  count: 2\n").unwrap()
        )
        .is_err());
    }

    #[test]
    fn scheduler_section_parses_policy_and_staleness() {
        let yaml = "\
mode: async
scheduler:
  policy: bounded_staleness
  max_version_lag: 3
sync:
  interval: 2
";
        let cfg = RftConfig::from_value(&yamlite::parse(yaml).unwrap()).unwrap();
        assert_eq!(cfg.scheduler.policy.as_deref(), Some("bounded_staleness"));
        assert_eq!(cfg.scheduler.max_version_lag, 3);
        let p = resolve_policy(&cfg).unwrap();
        assert_eq!(p.label(1), "staleness(i=2,lag=3,x1)");
    }

    #[test]
    fn scheduler_typed_keys_win_over_flat_sync_keys() {
        // mid-migration config carrying both spellings: typed wins
        let yaml = "\
mode: both
sync:
  interval: 10
  offset: 2
scheduler:
  interval: 4
  offset: 0
";
        let cfg = RftConfig::from_value(&yamlite::parse(yaml).unwrap()).unwrap();
        assert_eq!(cfg.sync_interval, 4);
        assert_eq!(cfg.sync_offset, 0);
    }

    #[test]
    fn unknown_scheduler_policy_fails_validation_with_catalog() {
        let yaml = "mode: both\nscheduler:\n  policy: warp\n";
        let err = RftConfig::from_value(&yamlite::parse(yaml).unwrap())
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown sync policy 'warp'"), "{err}");
        assert!(err.contains("bounded_staleness"), "error should list the registry: {err}");
    }

    #[test]
    fn multi_explorer_allowed_under_free_running_policies() {
        // seed rule: mode=both forbids multi-explorer...
        let bad = "mode: both\nexplorer:\n  count: 2\n";
        assert!(RftConfig::from_value(&yamlite::parse(bad).unwrap()).is_err());
        // ...but free-running policies (async, bounded staleness) allow it
        for yaml in [
            "mode: async\nexplorer:\n  count: 2\n",
            "mode: both\nscheduler:\n  policy: staleness\nexplorer:\n  count: 2\n",
        ] {
            assert!(
                RftConfig::from_value(&yamlite::parse(yaml).unwrap()).is_ok(),
                "should accept: {yaml}"
            );
        }
    }

    #[test]
    fn service_section_parses_and_validates() {
        let yaml = "\
mode: both
service:
  enabled: true
  replicas: 3
  max_batch: 4
  admission_window_ms: 5
  refill_chunk: 2
  timeout_s: 9.5
  max_attempts: 4
  retry_backoff_ms: 7
  breaker_failures: 2
  quarantine_s: 0.25
";
        let cfg = RftConfig::from_value(&yamlite::parse(yaml).unwrap()).unwrap();
        assert!(cfg.service.enabled);
        assert_eq!(cfg.service.replicas, 3);
        assert_eq!(cfg.service.max_batch, 4);
        let sc = cfg.service.to_service_config();
        assert_eq!(sc.admission_window, std::time::Duration::from_millis(5));
        assert_eq!(sc.refill_chunk, 2);
        assert!((sc.request_timeout.as_secs_f64() - 9.5).abs() < 1e-9);
        assert_eq!((sc.max_attempts, sc.breaker_failures), (4, 2));
        assert!((sc.quarantine.as_secs_f64() - 0.25).abs() < 1e-9);
        // defaults: single-replica service ON (the standard rollout
        // path), opt-out honored
        let d = RftConfig::from_value(&yamlite::parse("mode: both\n").unwrap()).unwrap();
        assert!(d.service.enabled);
        assert_eq!(d.service.replicas, 1);
        let off =
            RftConfig::from_value(&yamlite::parse("mode: both\nservice:\n  enabled: false\n").unwrap())
                .unwrap();
        assert!(!off.service.enabled);
        // bad knobs fail at config time
        let bad = "mode: both\nservice:\n  enabled: true\n  replicas: 0\n";
        assert!(RftConfig::from_value(&yamlite::parse(bad).unwrap()).is_err());
        let bad = "mode: both\nservice:\n  enabled: true\n  max_attempts: 0\n";
        assert!(RftConfig::from_value(&yamlite::parse(bad).unwrap()).is_err());
        let bad = "mode: both\nservice:\n  enabled: true\n  breaker_failures: 0\n";
        assert!(RftConfig::from_value(&yamlite::parse(bad).unwrap()).is_err());
        let bad = "mode: both\nservice:\n  enabled: true\n  timeout_s: 0\n";
        assert!(RftConfig::from_value(&yamlite::parse(bad).unwrap()).is_err());
    }

    #[test]
    fn service_cache_section_parses_and_validates() {
        let yaml = "\
mode: both
service:
  enabled: true
  cache_enabled: true
  cache_max_parked: 3
  cache_ttl_s: 45.5
  cache_min_prefix: 6
  cache_trie_tokens: 1024
  cache_overload_margin: 2
";
        let cfg = RftConfig::from_value(&yamlite::parse(yaml).unwrap()).unwrap();
        let sc = cfg.service.to_service_config();
        assert!(sc.cache.enabled);
        assert_eq!(sc.cache.max_parked, 3);
        assert!((sc.cache.park_ttl.as_secs_f64() - 45.5).abs() < 1e-9);
        assert_eq!((sc.cache.min_prefix, sc.cache.trie_tokens), (6, 1024));
        assert_eq!(sc.cache.overload_margin, 2);
        // defaults: cache on with sane knobs, off switch honored
        let d = RftConfig::default();
        assert!(d.service.cache_enabled);
        assert!(d.service.cache_max_parked >= 1);
        let off = "mode: both\nservice:\n  enabled: true\n  cache_enabled: false\n";
        let cfg = RftConfig::from_value(&yamlite::parse(off).unwrap()).unwrap();
        assert!(!cfg.service.to_service_config().cache.enabled);
        // bad knobs fail at config time
        let bad = "mode: both\nservice:\n  enabled: true\n  cache_min_prefix: 0\n";
        assert!(RftConfig::from_value(&yamlite::parse(bad).unwrap()).is_err());
        let bad = "mode: both\nservice:\n  enabled: true\n  cache_ttl_s: 0\n";
        assert!(RftConfig::from_value(&yamlite::parse(bad).unwrap()).is_err());
    }

    #[test]
    fn scheduler_buffer_pressure_knob_parses_into_free_policy() {
        let yaml = "\
mode: async
scheduler:
  max_buffer_depth: 64
";
        let cfg = RftConfig::from_value(&yamlite::parse(yaml).unwrap()).unwrap();
        assert_eq!(cfg.scheduler.max_buffer_depth, 64);
        let p = resolve_policy(&cfg).unwrap();
        assert!(p.label(1).contains("buf<64"), "{}", p.label(1));
        // default stays uncapped (the seed behavior)
        assert_eq!(RftConfig::default().scheduler.max_buffer_depth, 0);
    }

    #[test]
    fn scheduler_rotation_and_sharding_knobs_parse() {
        let yaml = "\
mode: async
scheduler:
  keep_checkpoints: 2
  shard_tasks: false
";
        let cfg = RftConfig::from_value(&yamlite::parse(yaml).unwrap()).unwrap();
        assert_eq!(cfg.scheduler.keep_checkpoints, 2);
        assert!(!cfg.scheduler.shard_tasks);
        // rotation stays opt-in: the default must never delete
        // checkpoints that bench-over-checkpoints workflows read
        let d = RftConfig::default();
        assert_eq!(d.scheduler.keep_checkpoints, 0);
        assert!(d.scheduler.shard_tasks);
    }

    #[test]
    fn observability_section_parses_and_validates() {
        let yaml = "\
mode: both
observability:
  enabled: true
  ring_capacity: 2048
  sample_every_s: 0.5
  trace_path: /tmp/t/trace.json
";
        let cfg = RftConfig::from_value(&yamlite::parse(yaml).unwrap()).unwrap();
        assert!(cfg.observability.enabled);
        let oc = cfg.observability.to_obs_config();
        assert_eq!(oc.ring_capacity, 2048);
        assert!((oc.sample_every.as_secs_f64() - 0.5).abs() < 1e-9);
        assert_eq!(oc.trace_path.as_deref(), Some(std::path::Path::new("/tmp/t/trace.json")));
        // defaults: off, zero overhead
        let off = RftConfig::from_value(&yamlite::parse("mode: both\n").unwrap()).unwrap();
        assert!(!off.observability.enabled);
        // bad knobs fail at config time (only when enabled)
        let bad = "mode: both\nobservability:\n  enabled: true\n  ring_capacity: 0\n";
        assert!(RftConfig::from_value(&yamlite::parse(bad).unwrap()).is_err());
        let bad = "mode: both\nobservability:\n  enabled: true\n  sample_every_s: 0\n";
        assert!(RftConfig::from_value(&yamlite::parse(bad).unwrap()).is_err());
        let ok = "mode: both\nobservability:\n  ring_capacity: 0\n"; // disabled: not validated
        assert!(RftConfig::from_value(&yamlite::parse(ok).unwrap()).is_ok());
    }

    #[test]
    fn diagnostics_knobs_parse_into_flight_and_slo_configs() {
        let yaml = "\
mode: both
observability:
  enabled: true
  gauge_history: 64
  critical_top_k: 3
  flight_max_dumps: 4
  flight_min_interval_s: 2.5
  flight_expiry_burst: 16
  flight_expiry_window_s: 1.0
  flight_span_tail: 128
  flight_burn_threshold: 3.5
  slo_interactive_s: 0.25
  slo_objective: 0.95
";
        let cfg = RftConfig::from_value(&yamlite::parse(yaml).unwrap()).unwrap();
        let oc = cfg.observability.to_obs_config();
        assert_eq!(oc.gauge_history, 64);
        assert_eq!(oc.critical_top_k, 3);
        assert_eq!(oc.flight.max_dumps, 4);
        assert!((oc.flight.min_interval.as_secs_f64() - 2.5).abs() < 1e-9);
        assert_eq!(oc.flight.expiry_burst, 16);
        assert!((oc.flight.expiry_window.as_secs_f64() - 1.0).abs() < 1e-9);
        assert_eq!(oc.flight.span_tail, 128);
        assert!((oc.flight.burn_threshold - 3.5).abs() < 1e-9);
        assert!(oc.flight.dir.is_none(), "dir is filled at session build");
        use crate::qos::RequestClass;
        assert!(oc.slo.any_target());
        assert!(
            (oc.slo.targets[RequestClass::Interactive.index()].as_secs_f64() - 0.25).abs() < 1e-9
        );
        assert!(oc.slo.targets[RequestClass::TrainRollout.index()].is_zero());
        assert!((oc.slo.objective - 0.95).abs() < 1e-9);
        // defaults: no SLO targets, recorder knobs mirror FlightConfig
        let d = RftConfig::default().observability.to_obs_config();
        assert!(!d.slo.any_target());
        assert_eq!(d.flight.max_dumps, crate::obs::FlightConfig::default().max_dumps);
        // bad knobs fail at config time (only when enabled)
        let bad = "mode: both\nobservability:\n  enabled: true\n  slo_objective: 1.0\n";
        assert!(RftConfig::from_value(&yamlite::parse(bad).unwrap()).is_err());
        let bad = "mode: both\nobservability:\n  enabled: true\n  flight_burn_threshold: -1\n";
        assert!(RftConfig::from_value(&yamlite::parse(bad).unwrap()).is_err());
        let ok = "mode: both\nobservability:\n  slo_objective: 1.0\n"; // disabled: not validated
        assert!(RftConfig::from_value(&yamlite::parse(ok).unwrap()).is_ok());
    }

    #[test]
    fn config_digest_is_stable_and_knob_sensitive() {
        let a = RftConfig::default();
        let b = RftConfig::default();
        assert_eq!(a.digest(), b.digest(), "identical configs digest identically");
        assert_eq!(a.digest().len(), 16);
        let mut c = RftConfig::default();
        c.seed = 43;
        assert_ne!(a.digest(), c.digest(), "any knob change moves the digest");
    }

    #[test]
    fn typed_sections_take_precedence_over_flat_keys() {
        // mid-migration config carrying both spellings: the typed
        // section wins
        let yaml = "mode: train\nalgorithm:\n  name: opmd_kimi\n  tau: 2.0\n  opmd:\n    tau: 0.7\n";
        let cfg = RftConfig::from_value(&yamlite::parse(yaml).unwrap()).unwrap();
        assert!((cfg.opmd.tau - 0.7).abs() < 1e-6);
    }

    #[test]
    fn control_section_parses_and_validates() {
        let yaml = "\
mode: both
control:
  enabled: true
  max_gauge_age_s: 5.0
  hold_ticks: 3
  staleness_hi: 0.6
  staleness_lo: 0.2
  wait_hi_s: 0.5
  queue_hi: 8
  quarantine_hi: 0.25
  release: 0.5
  capacity_headroom: 1.5
  min_batch_tasks: 2
  max_batch_tasks: 12
";
        let cfg = RftConfig::from_value(&yamlite::parse(yaml).unwrap()).unwrap();
        assert!(cfg.control.enabled);
        let cc = cfg.control.to_control_config();
        assert!((cc.max_gauge_age_s - 5.0).abs() < 1e-9);
        assert_eq!(cc.hold_ticks, 3);
        assert!((cc.staleness_hi - 0.6).abs() < 1e-9);
        assert!((cc.staleness_lo - 0.2).abs() < 1e-9);
        assert!((cc.wait_hi_s - 0.5).abs() < 1e-9);
        assert!((cc.queue_hi - 8.0).abs() < 1e-9);
        assert!((cc.quarantine_hi - 0.25).abs() < 1e-9);
        assert!((cc.release - 0.5).abs() < 1e-9);
        assert!((cc.capacity_headroom - 1.5).abs() < 1e-9);
        assert_eq!((cc.min_batch_tasks, cc.max_batch_tasks), (2, 12));
        // defaults: control off, zero behavioral delta
        let off = RftConfig::from_value(&yamlite::parse("mode: both\n").unwrap()).unwrap();
        assert!(!off.control.enabled);
        // bad bands fail at config time (only when enabled)
        let bad = "mode: both\ncontrol:\n  enabled: true\n  release: 1.5\n";
        assert!(RftConfig::from_value(&yamlite::parse(bad).unwrap()).is_err());
        let bad = "mode: both\ncontrol:\n  enabled: true\n  staleness_lo: 0.9\n";
        assert!(RftConfig::from_value(&yamlite::parse(bad).unwrap()).is_err());
        let bad = "mode: both\ncontrol:\n  enabled: true\n  hold_ticks: 0\n";
        assert!(RftConfig::from_value(&yamlite::parse(bad).unwrap()).is_err());
        let ok = "mode: both\ncontrol:\n  release: 1.5\n"; // disabled: not validated
        assert!(RftConfig::from_value(&yamlite::parse(ok).unwrap()).is_ok());
    }

    #[test]
    fn qos_section_parses_and_validates() {
        let yaml = "\
mode: both
qos:
  enabled: true
  train_weight: 8
  eval_weight: 3
  interactive_weight: 5
  quantum: 2
  aging_ms: 250
  interactive_deadline_s: 1.5
  eval_cap: 32
  migration: false
  migrate_min_tokens: 64
";
        let cfg = RftConfig::from_value(&yamlite::parse(yaml).unwrap()).unwrap();
        assert!(cfg.qos.enabled);
        let qc = cfg.qos.to_qos_config();
        assert_eq!(qc.weights, [8, 3, 5]);
        assert_eq!(qc.quantum, 2);
        assert_eq!(qc.aging, std::time::Duration::from_millis(250));
        use crate::qos::RequestClass;
        assert!(
            (qc.deadlines[RequestClass::Interactive.index()].as_secs_f64() - 1.5).abs() < 1e-9
        );
        assert!(qc.deadlines[RequestClass::TrainRollout.index()].is_zero(), "unset inherits");
        assert_eq!(qc.cap_for(RequestClass::Eval), Some(32));
        assert_eq!(qc.cap_for(RequestClass::TrainRollout), None);
        assert!(!qc.migration);
        assert_eq!(qc.migrate_min_tokens, 64);
        // defaults: qos off, zero behavioral delta
        let off = RftConfig::from_value(&yamlite::parse("mode: both\n").unwrap()).unwrap();
        assert!(!off.qos.enabled);
        // bad knobs fail at config time (only when enabled)
        let bad = "mode: both\nqos:\n  enabled: true\n  eval_weight: 0\n";
        assert!(RftConfig::from_value(&yamlite::parse(bad).unwrap()).is_err());
        let bad = "mode: both\nqos:\n  enabled: true\n  quantum: 0\n";
        assert!(RftConfig::from_value(&yamlite::parse(bad).unwrap()).is_err());
        let ok = "mode: both\nqos:\n  quantum: 0\n"; // disabled: not validated
        assert!(RftConfig::from_value(&yamlite::parse(ok).unwrap()).is_ok());
    }

    #[test]
    fn adaptive_policy_resolves_from_config() {
        let yaml = "\
mode: async
scheduler:
  policy: adaptive
  max_version_lag: 3
sync:
  interval: 2
control:
  enabled: true
";
        let cfg = RftConfig::from_value(&yamlite::parse(yaml).unwrap()).unwrap();
        let p = resolve_policy(&cfg).unwrap();
        assert_eq!(p.label(1), "adaptive(i=2,lag<=3,x1)");
        assert!(p.multi_explorer(), "adaptive is free-running");
    }
}
