//! Data pipelines in action (paper §3.4): task curation for curriculum
//! learning + dynamic quality-reward shaping — the two use cases of
//! Figs. 10 and 12, driven end-to-end.

use std::sync::Arc;

use trinity_rft::coordinator::{PrioritizedTaskSource, RftConfig, RftSession, TaskSource};
use trinity_rft::data::{agentic, QualityRewardProcessor, TaskPipeline};
use trinity_rft::envs::math::MathTaskGen;
use trinity_rft::explorer::Task;

fn main() -> anyhow::Result<()> {
    trinity_rft::util::logging::init_from_env();
    let steps: u64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(4);

    // === stage 1: task curation & prioritization (Fig. 5 left) ===
    let mut gen = MathTaskGen::new(31, "curated");
    let raw: Vec<Task> = gen
        .gen_batch(24, 1, 8)
        .into_iter()
        .map(|mt| {
            let mut t = Task::new(&mt.id, "math", mt.to_payload());
            t.difficulty = mt.difficulty as f64;
            t.repeat_times = 4;
            t
        })
        .collect();
    println!("raw task difficulties: {:?}", raw.iter().map(|t| t.difficulty as u8).collect::<Vec<_>>());

    // 'priority_weights: difficulty: -1.0' -> easy-to-hard (paper Listing 5)
    let curated = TaskPipeline::easy_to_hard().run(raw)?;
    println!(
        "curated (easy->hard):  {:?}",
        curated.iter().map(|t| t.difficulty as u8).collect::<Vec<_>>()
    );

    // === stage 2: agentic pipeline from a natural-language command ===
    let tokenizer = Arc::new(trinity_rft::tokenizer::Tokenizer::new());
    let plan = agentic::translate_command("improve quality and dedup responses", tokenizer);
    println!("\nagentic command -> stages: {:?}", plan.stages);

    // === stage 3: train with curriculum + quality shaping (Fig. 12) ===
    let mut cfg = RftConfig::default();
    cfg.mode = "both".into();
    cfg.total_steps = steps;
    cfg.batch_tasks = 1;
    cfg.repeat_times = 4;
    cfg.max_new_tokens = 6;
    cfg.sync_interval = 3; // the paper's Fig. 12 setting
    cfg.hyper.lr = 5e-4;

    let eval = curated[..4].to_vec();
    let source: Arc<dyn TaskSource> = Arc::new(PrioritizedTaskSource::new(curated, eval));
    let shaping = Arc::new(QualityRewardProcessor { weight: 1.0 });
    let mut session = RftSession::build(cfg, Some(source), Some(shaping))?;
    let report = session.run()?;

    println!("\nstep  shaped_reward  resp_len");
    for m in &report.trainer_metrics {
        println!("{:<5} {:<14.3} {:<9.1}", m.step, m.mean_reward, m.mean_response_len);
    }
    println!(
        "\nshaped reward = rule reward + quality in [-0.5, 0.5], recomputed \
         per RFT step against the evolving policy (dynamic, not static)"
    );
    println!("wall {:.1}s over {} steps", report.wall_s, report.train_steps);
    Ok(())
}
