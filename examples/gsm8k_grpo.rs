//! End-to-end driver (DESIGN.md §5): train the policy LLM from scratch
//! with GRPO on the synthetic verifiable-math workload for a few hundred
//! steps, log the reward/loss curve, evaluate checkpoints on the four
//! benchmark tiers in bench mode, and save the final checkpoint.
//!
//! The recorded run for EXPERIMENTS.md:
//! ```sh
//! cargo run --release --example gsm8k_grpo -- 300 tiny
//! ```
//! (steps and preset are positional; defaults 300 / tiny.)

use std::sync::Arc;

use trinity_rft::coordinator::{RftConfig, RftSession};
use trinity_rft::data::formatter::{FormatSpec, Formatter};
use trinity_rft::envs::math::MathTaskGen;
use trinity_rft::util::benchkit::{sparkline, write_json, Table};
use trinity_rft::util::json::Value;
use trinity_rft::util::timeseries::{moving_average, summarize};

/// SFT warm-up (the paper's `sft_warmup_dataset`): a cold-started random
/// model never emits a valid digit, so GRPO sees all-zero group rewards
/// and no gradient.  A short SFT phase on gold answers gives the RL phase
/// a non-degenerate reward signal — standard practice and natively
/// supported by the framework (train-only mode + expert buffer).
fn sft_warmup(preset: &str, seed: u64, steps: u64) -> anyhow::Result<Vec<Vec<f32>>> {
    let mut cfg = RftConfig::default();
    cfg.mode = "train".into();
    cfg.algorithm = "sft".into();
    cfg.model_preset = preset.into();
    cfg.total_steps = steps;
    cfg.seed = seed;
    cfg.hyper.lr = 2e-3;
    let mut session = RftSession::build(cfg, None, None)?;
    let formatter =
        Formatter { spec: FormatSpec::default(), tokenizer: Arc::clone(&session.tokenizer) };
    let (b, _, _) = session.engine.train_shape("sft")?;
    let mut gen = MathTaskGen::new(seed ^ 0x5f7, "warmup");
    let mut exps = vec![];
    for _ in 0..(steps as usize * b) {
        let t = gen.gen(1);
        let raw = Value::obj(vec![
            ("question", Value::str(t.question.clone())),
            ("answer", Value::str(t.answer.to_string())),
        ]);
        exps.push(formatter.to_expert_experience(&raw)?);
    }
    session.buffer.write(exps)?;
    let report = session.run()?;
    let losses = report.series("loss");
    println!(
        "warmup SFT: {} steps, nll {:.3} -> {:.3}",
        steps,
        losses.first().unwrap_or(&0.0),
        losses.last().unwrap_or(&0.0)
    );
    session.trainer.as_ref().unwrap().params().snapshot().map_err(Into::into)
}

fn main() -> anyhow::Result<()> {
    trinity_rft::util::logging::init_from_env();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: u64 = args.first().and_then(|s| s.parse().ok()).unwrap_or(300);
    let preset = args.get(1).cloned().unwrap_or_else(|| "tiny".to_string());
    let warmup_steps: u64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(150);

    let mut cfg = RftConfig::default();
    cfg.mode = "both".into();
    cfg.model_preset = preset.clone();
    cfg.algorithm = "grpo".into();
    cfg.total_steps = steps;
    cfg.sync_interval = 1;
    cfg.sync_offset = 1; // one-step off-policy: paper's best speed/quality point
    cfg.batch_tasks = 1;
    cfg.repeat_times = if preset == "small" { 8 } else { 4 };
    cfg.max_new_tokens = 6;
    cfg.min_difficulty = 1;
    cfg.max_difficulty = 1; // single-op single-digit: learnable from scratch
    cfg.temperature = 0.9;
    cfg.hyper.lr = 5e-4;
    cfg.hyper.clip_eps = 0.2;
    cfg.adv_std_normalize = true;
    cfg.eval_every = (steps / 5).max(1);
    cfg.monitor_dir = Some(std::path::PathBuf::from(format!("runs/gsm8k_grpo_{preset}")));

    println!("=== e2e GRPO training: preset={preset}, {warmup_steps} SFT warmup + {steps} RL steps ===");
    let t0 = std::time::Instant::now();
    let warm = sft_warmup(&preset, 42, warmup_steps)?;
    let mut session = RftSession::build(cfg, None, None)?;
    // both trainer and explorer start from the warmed-up weights
    session.load_initial_weights(&warm)?;
    println!(
        "model: {} params | warmup+compile+wiring {:.1}s",
        session.engine.model.param_count,
        t0.elapsed().as_secs_f64()
    );

    // baseline eval before training
    let tiers = ["math500s", "amcs", "aime24s", "aime25s"];
    let before = session.run_bench(&tiers, 16, 4, 0.6)?;

    let report = session.run()?;

    // loss / reward curves (40-step moving average like Fig. 9)
    let rewards = report.reward_series();
    let losses = report.series("loss");
    let smoothed = moving_average(&rewards, 40.min(rewards.len()));
    println!("\nreward curve  {}", sparkline(&smoothed));
    println!("loss curve    {}", sparkline(&moving_average(&losses, 40.min(losses.len()))));
    let early = summarize(&rewards[..(rewards.len() / 5).max(1)]);
    let late = summarize(&rewards[rewards.len() - (rewards.len() / 5).max(1)..]);
    println!(
        "reward: first fifth {:.3} -> last fifth {:.3} (x{:.2})",
        early.mean,
        late.mean,
        late.mean / early.mean.max(1e-9)
    );

    // bench-mode eval over the training snapshots (paper §2.1.1 bench mode)
    let mut table = Table::new(
        "e2e evaluation (Avg@4 per tier)",
        &["checkpoint", "math500s", "amcs", "aime24s", "aime25s"],
    );
    let fmt_row = |name: &str, evals: &[(String, trinity_rft::explorer::EvalReport)]| {
        let mut cells = vec![name.to_string()];
        cells.extend(evals.iter().map(|(_, r)| format!("{:.3}", r.avg_reward)));
        cells
    };
    table.row(fmt_row("init", &before));
    for (step, weights) in &report.snapshots {
        session.load_explorer_weights(weights, 1000 + step)?;
        let evals = session.run_bench(&tiers, 16, 4, 0.6)?;
        table.row(fmt_row(&format!("step {step}"), &evals));
    }
    table.print();

    // persist the final checkpoint
    std::fs::create_dir_all("runs")?;
    let ckpt = format!("runs/gsm8k_grpo_{preset}.ckpt");
    session.trainer.as_ref().unwrap().save_checkpoint(&ckpt)?;
    println!("\nsaved {ckpt}");
    println!(
        "wall {:.1}s | {} steps | explorer util {:.1}% | trainer util {:.1}%",
        report.wall_s, report.train_steps, report.explorer_util, report.trainer_util
    );

    let mut out = table.to_json();
    out.set("wall_s", Value::num(report.wall_s));
    out.set("steps", Value::num(report.train_steps as f64));
    out.set("reward_first_fifth", Value::num(early.mean));
    out.set("reward_last_fifth", Value::num(late.mean));
    out.set(
        "reward_series",
        Value::arr(rewards.iter().map(|r| Value::num(*r)).collect()),
    );
    write_json(&format!("e2e_gsm8k_grpo_{preset}"), &out);
    session.monitor.flush_csv()?;
    Ok(())
}
