//! Human-in-the-loop RFT (paper §3.5): model rollouts -> annotation
//! batches -> simulated annotator pool (Label Studio stand-in) ->
//! quality-controlled preference pairs -> DPO in train-only mode.

use std::sync::Arc;
use std::time::Duration;

use trinity_rft::coordinator::{RftConfig, RftSession};
use trinity_rft::data::formatter::{FormatSpec, Formatter};
use trinity_rft::data::human::{
    results_to_preference_pairs, AnnotationItem, AnnotationService, AnnotatorConfig,
};
use trinity_rft::envs::math::MathTaskGen;

fn main() -> anyhow::Result<()> {
    trinity_rft::util::logging::init_from_env();

    // === stage 1: candidate responses (normally: model rollouts) ===
    let mut gen = MathTaskGen::new(5, "pref");
    let items: Vec<AnnotationItem> = (0..8)
        .map(|i| {
            let t = gen.gen(1);
            AnnotationItem {
                prompt: t.question.clone(),
                answer_a: if i % 2 == 0 { t.answer.to_string() } else { "99".into() },
                answer_b: if i % 2 == 0 { "99".into() } else { t.answer.to_string() },
                gold_answer: t.answer,
            }
        })
        .collect();

    // === stage 2: async annotation with timeout-aware polling ===
    let svc = AnnotationService::new(
        AnnotatorConfig {
            annotators_per_item: 3,
            accuracy: 0.9,
            mean_latency: Duration::from_millis(30),
            min_agreement: 0.6,
        },
        4,
        42,
    );
    let batch_id = svc.post_batch(items.clone());
    println!("posted annotation batch {batch_id} (8 items, 3 annotators each)");
    println!("status while annotators work: {:?}", svc.status(batch_id));
    // ... the RFT loop would keep exploring here (async model) ...
    let results = svc.wait_for_batch(batch_id, Duration::from_secs(10))?;
    println!(
        "batch committed atomically: {} items passed agreement QC",
        results.len()
    );
    for (idx, r) in &results {
        println!(
            "  item {idx}: chose {} (agreement {:.0}%)",
            if r.chosen_is_a { "A" } else { "B" },
            r.agreement * 100.0
        );
    }

    // === stage 3: preferences -> DPODataModel pairs -> train-only DPO ===
    let mut cfg = RftConfig::default();
    cfg.mode = "train".into();
    cfg.algorithm = "dpo".into();
    cfg.dpo.beta = 0.5;
    cfg.hyper.lr = 5e-4;
    // tiny dpo artifact trains 2 pairs/step
    cfg.total_steps = (results.len() as u64 / 2).max(1);
    let mut session = RftSession::build(cfg, None, None)?;
    let formatter =
        Formatter { spec: FormatSpec::default(), tokenizer: Arc::clone(&session.tokenizer) };
    let pairs = results_to_preference_pairs(&items, &results, &formatter)?;
    println!("\nwrote {} chosen/rejected experiences to the buffer", pairs.len());
    session.buffer.write(pairs)?;

    let report = session.run()?;
    println!("\nstep  dpo_loss  margin    accuracy");
    for m in &report.trainer_metrics {
        println!(
            "{:<5} {:<9.4} {:<9.4} {:<8.2}",
            m.step,
            m.get("loss").unwrap_or(0.0),
            m.get("margin").unwrap_or(0.0),
            m.get("accuracy").unwrap_or(0.0)
        );
    }
    println!("\nhuman feedback entered the RL loop without breaking the async model");
    Ok(())
}
