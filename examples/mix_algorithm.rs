//! The MIX algorithm (paper §3.2, Fig. 8): online GRPO on rollout
//! experiences + SFT on expert trajectories, in one training loop.
//!
//! Exactly the paper's three plug-in pieces, in Rust form:
//!   * `MixSampleStrategy`  — batch = usual buffer + expert buffer
//!   * the `mix` loss       — (1-mu) * GRPO + mu * SFT (an L2 artifact)
//!   * the `mix` algorithm  — wired through TrainerConfig
//!
//! The expert buffer is filled from formatter-converted gold QA pairs.

use std::sync::Arc;
use std::time::Duration;

use trinity_rft::buffer::{ExperienceBuffer, MixSampleStrategy, QueueBuffer};
use trinity_rft::coordinator::{MathTaskSource, RftConfig, RftSession, TaskSource};
use trinity_rft::data::formatter::{FormatSpec, Formatter};
use trinity_rft::envs::math::MathTaskGen;
use trinity_rft::model::ParamStore;
use trinity_rft::trainer::{Trainer, TrainerConfig};
use trinity_rft::util::json::Value;

fn main() -> anyhow::Result<()> {
    trinity_rft::util::logging::init_from_env();
    let steps: u64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(6);

    // a standard session provides engine + explorer + rollout buffer
    let mut cfg = RftConfig::default();
    cfg.mode = "both".into();
    cfg.algorithm = "mix".into();
    cfg.total_steps = steps;
    cfg.batch_tasks = 1;
    cfg.repeat_times = 3; // 3 rollouts + 1 expert = tiny batch of 4
    cfg.max_new_tokens = 6;
    cfg.hyper.lr = 5e-4;
    cfg.hyper.mu = 0.25; // SFT weight on the expert slice
    let mut session = RftSession::build(cfg.clone(), None, None)?;

    // --- expert buffer: gold answers as high-quality trajectories ---
    let formatter =
        Formatter { spec: FormatSpec::default(), tokenizer: Arc::clone(&session.tokenizer) };
    let expert_buffer = Arc::new(QueueBuffer::new(4096));
    let mut gen = MathTaskGen::new(99, "expert");
    let mut experts = vec![];
    for _ in 0..(steps as usize + 2) {
        let t = gen.gen(1);
        let raw = Value::obj(vec![
            ("question", Value::str(t.question.clone())),
            ("answer", Value::str(t.answer.to_string())),
        ]);
        experts.push(formatter.to_expert_experience(&raw)?);
    }
    let n_expert = experts.len();
    expert_buffer.write(experts)?;

    // --- swap in the MIX sample strategy (the paper's MixSampleStrategy) ---
    let strategy = Box::new(MixSampleStrategy {
        usual: Arc::clone(&session.buffer),
        expert: expert_buffer,
        expert_fraction: 0.25, // 1 of 4 per batch
        timeout: Duration::from_secs(600),
    });
    let mut tcfg = TrainerConfig::new("mix");
    tcfg.algorithm.hyper = cfg.effective_hyper();
    let params = ParamStore::init(&session.engine.model, cfg.seed)?;
    // explorer must start from the same weights
    session.load_explorer_weights(&params.snapshot()?, 0)?;
    session.trainer = Some(Trainer::new(Arc::clone(&session.engine), params, strategy, tcfg)?);

    println!("MIX: {} expert trajectories + online rollouts, mu=0.25", n_expert);
    let source: Arc<dyn TaskSource> = Arc::new(MathTaskSource::new(7, 1, 1, 3));
    session.task_source = source;
    let report = session.run()?;

    println!("\nstep  loss      grpo_loss  sft_loss  expert_frac");
    for m in &report.trainer_metrics {
        println!(
            "{:<5} {:<9.4} {:<10.4} {:<9.4} {:<6.2}",
            m.step,
            m.get("loss").unwrap_or(0.0),
            m.get("grpo_loss").unwrap_or(0.0),
            m.get("sft_loss").unwrap_or(0.0),
            m.get("expert_frac").unwrap_or(0.0)
        );
    }
    println!(
        "\nevery batch mixed {}% expert data into the GRPO stream (one loss, two sources)",
        25
    );
    println!("wall {:.1}s over {} steps", report.wall_s, report.train_steps);
    Ok(())
}
