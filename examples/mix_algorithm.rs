//! A custom algorithm as a *registration*, not a trainer fork (paper
//! §3.2, Fig. 8; DESIGN.md §4).
//!
//! The composable algorithm API decomposes an RL algorithm into
//! pluggable modules — advantage fn, grouping policy, loss spec, extra
//! inputs, linked sample strategy.  Here we assemble `mix_boosted`, a
//! MIX variant (online GRPO on rollouts + SFT on expert rows) with
//! std-normalized advantages, in ~20 lines of spec assembly:
//!
//!   * `GroupBaseline { std_normalize: true }` — the advantage module
//!   * `LossSpec::pg_clip_mix()`  — (1-mu) * GRPO + mu * SFT (the
//!     compiled `mix` L2 artifact, reused under the custom name)
//!   * `IsExpertFlag`             — extra per-row input for the loss
//!   * `MixFactory`               — batch = usual buffer + expert buffer
//!
//! Nothing under `rust/src/trainer/` is modified: the registry entry IS
//! the algorithm.  The expert buffer is filled from formatter-converted
//! gold QA pairs and handed to the session via `BuildOpts`.

use std::sync::Arc;

use trinity_rft::buffer::{ExperienceBuffer, MixFactory, QueueBuffer};
use trinity_rft::coordinator::{BuildOpts, MathTaskSource, RftConfig, RftSession, TaskSource};
use trinity_rft::data::formatter::{FormatSpec, Formatter};
use trinity_rft::envs::math::MathTaskGen;
use trinity_rft::tokenizer::Tokenizer;
use trinity_rft::trainer::{
    AlgorithmRegistry, AlgorithmSpec, GroupBaseline, GroupingPolicy, IsExpertFlag, LossSpec,
};
use trinity_rft::util::json::Value;

fn main() -> anyhow::Result<()> {
    trinity_rft::util::logging::init_from_env();
    let steps: u64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(6);

    // --- the custom algorithm: one registration, zero trainer edits ---
    AlgorithmRegistry::global().register(
        AlgorithmSpec::new("mix_boosted", "mix") // reuse the compiled `mix` artifact
            .advantage(GroupBaseline { std_normalize: true })
            .grouping(GroupingPolicy::GroupBaseline)
            .old_logprobs(true)
            .loss(LossSpec::pg_clip_mix())
            .extra(IsExpertFlag)
            .sample(MixFactory)
            .about("MIX with std-normalized group advantages (example-registered)"),
    );

    let mut cfg = RftConfig::default();
    cfg.mode = "both".into();
    cfg.algorithm = "mix_boosted".into();
    cfg.total_steps = steps;
    cfg.batch_tasks = 1;
    cfg.repeat_times = 3; // 3 rollouts + 1 expert = tiny batch of 4
    cfg.max_new_tokens = 6;
    cfg.hyper.lr = 5e-4;
    cfg.mix.mu = 0.25; // SFT weight on the expert slice
    cfg.mix.expert_fraction = 0.25; // 1 of 4 per batch

    // --- expert buffer: gold answers as high-quality trajectories ---
    let formatter =
        Formatter { spec: FormatSpec::default(), tokenizer: Arc::new(Tokenizer::new()) };
    let expert_buffer = Arc::new(QueueBuffer::new(4096));
    let mut gen = MathTaskGen::new(99, "expert");
    let mut experts = vec![];
    for _ in 0..(steps as usize + 2) {
        let t = gen.gen(1);
        let raw = Value::obj(vec![
            ("question", Value::str(t.question.clone())),
            ("answer", Value::str(t.answer.to_string())),
        ]);
        experts.push(formatter.to_expert_experience(&raw)?);
    }
    let n_expert = experts.len();
    expert_buffer.write(experts)?;

    // the spec's MixFactory picks the expert buffer up from BuildOpts
    let source: Arc<dyn TaskSource> = Arc::new(MathTaskSource::new(7, 1, 1, 3));
    let mut session = RftSession::build_with(
        cfg,
        BuildOpts {
            task_source: Some(source),
            expert_buffer: Some(expert_buffer),
            ..Default::default()
        },
    )?;

    println!("mix_boosted: {} expert trajectories + online rollouts, mu=0.25", n_expert);
    let report = session.run()?;

    println!("\nstep  loss      grpo_loss  sft_loss  expert_frac");
    for m in &report.trainer_metrics {
        println!(
            "{:<5} {:<9.4} {:<10.4} {:<9.4} {:<6.2}",
            m.step,
            m.get("loss").unwrap_or(0.0),
            m.get("grpo_loss").unwrap_or(0.0),
            m.get("sft_loss").unwrap_or(0.0),
            m.get("expert_frac").unwrap_or(0.0)
        );
    }
    println!(
        "\nevery batch mixed {}% expert data into the GRPO stream (one loss, two sources)",
        25
    );
    println!("wall {:.1}s over {} steps", report.wall_s, report.train_steps);
    Ok(())
}
