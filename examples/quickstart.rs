//! Quickstart: the full stack in ~60 lines.
//!
//! Loads the AOT artifacts, wires the explorer/buffer/trainer trinity on
//! the tiny preset, runs a few synchronous GRPO steps on synthetic math,
//! and prints the metrics.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use trinity_rft::coordinator::{RftConfig, RftSession};
use trinity_rft::util::timeseries;

fn main() -> anyhow::Result<()> {
    trinity_rft::util::logging::init_from_env();

    let mut cfg = RftConfig::default();
    cfg.mode = "both".into(); // synchronous (Fig. 4a)
    cfg.model_preset = "tiny".into();
    cfg.algorithm = "grpo".into();
    cfg.total_steps = 5;
    cfg.sync_interval = 1; // strictly on-policy
    cfg.batch_tasks = 1;
    cfg.repeat_times = 4; // GRPO group size = tiny batch bucket
    cfg.max_new_tokens = 6;
    cfg.min_difficulty = 1;
    cfg.max_difficulty = 1;
    cfg.hyper.lr = 5e-4;

    println!("building session (compiling {} artifacts)...", cfg.model_preset);
    let mut session = RftSession::build(cfg, None, None)?;
    println!(
        "model '{}': {} params, algorithms: {:?}",
        session.engine.model.name,
        session.engine.model.param_count,
        session.engine.algorithms()
    );

    let report = session.run()?;

    println!("\nstep  reward  loss      kl        entropy   resp_len");
    for m in &report.trainer_metrics {
        println!(
            "{:<5} {:<7.3} {:<9.4} {:<9.5} {:<9.3} {:<8.1}",
            m.step,
            m.mean_reward,
            m.get("loss").unwrap_or(0.0),
            m.get("kl").unwrap_or(0.0),
            m.get("entropy").unwrap_or(0.0),
            m.mean_response_len,
        );
    }
    let rewards = report.reward_series();
    println!(
        "\n{} train steps in {:.1}s — reward {}",
        report.train_steps,
        report.wall_s,
        timeseries::fmt_mean_std(&timeseries::summarize(&rewards))
    );
    println!("explorer util {:.1}%, trainer util {:.1}%", report.explorer_util, report.trainer_util);

    // bench mode on two held-out tiers
    let bench = session.run_bench(&["math500s", "amcs"], 4, 2, 0.6)?;
    println!("\nbench (Avg@2):");
    for (tier, r) in bench {
        println!("  {:<10} avg_reward={:.3} pass@k={:.3}", tier, r.avg_reward, r.pass_at_k);
    }
    Ok(())
}
