//! Multi-turn agentic workflow (paper §3.1.2, Listing 2): the grid-world
//! ALFWorld stand-in.  Episodes are packed into single masked sequences
//! (observation tokens masked out of the loss), then trained with GRPO in
//! the synchronous mode.

use trinity_rft::coordinator::{RftConfig, RftSession};
use trinity_rft::envs::alfworld::{AlfworldEnv, DEFAULT_MAX_STEPS};
use trinity_rft::util::timeseries::summarize;

fn main() -> anyhow::Result<()> {
    trinity_rft::util::logging::init_from_env();
    let steps: u64 =
        std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(5);

    // 1. show one scripted episode for orientation
    let mut env = AlfworldEnv::create(3, DEFAULT_MAX_STEPS, std::time::Duration::ZERO);
    println!("goal: {}", env.goal_text());
    println!("obs : {}", env.observe());
    for action in env.optimal_plan() {
        let text = AlfworldEnv::action_text(&action);
        let (obs, reward, done) = env.step(&action);
        println!("  > {text:<18} -> {obs} (r={reward})");
        if done {
            break;
        }
    }

    // 2. RFT on multi-turn episodes
    let mut cfg = RftConfig::default();
    cfg.mode = "both".into();
    cfg.workflow = "alfworld".into();
    cfg.algorithm = "grpo".into();
    cfg.model_preset = "tiny".into();
    cfg.total_steps = steps;
    cfg.sync_interval = 2;
    cfg.batch_tasks = 1;
    cfg.repeat_times = 4;
    cfg.max_new_tokens = 5; // one action per turn
    cfg.hyper.lr = 5e-4;

    println!("\ntraining {} steps on multi-turn episodes...", cfg.total_steps);
    let mut session = RftSession::build(cfg, None, None)?;
    let report = session.run()?;

    println!("\nstep  reward   resp_tokens  kl");
    for m in &report.trainer_metrics {
        println!(
            "{:<5} {:<8.3} {:<12.1} {:<9.5}",
            m.step,
            m.mean_reward,
            m.mean_response_len,
            m.get("kl").unwrap_or(0.0)
        );
    }
    let lens = report.response_len_series();
    println!(
        "\npacked sequences: response tokens {} over {} steps — multi-turn \
         episodes compact into ONE sequence each (K-turn != K samples)",
        summarize(&lens).mean.round(),
        report.train_steps
    );
    println!("wall {:.1}s, explorer util {:.1}%", report.wall_s, report.explorer_util);
    Ok(())
}
