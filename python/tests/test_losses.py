"""L2 algorithm correctness: loss identities and one-step learning direction."""

import jax
import jax.numpy as jnp
import pytest

from compile import losses, model
from compile.losses import H_CLIP, H_LR, H_MU, H_TAU

CFG = model.PRESETS["tiny"]
B, T = 4, 64


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG, jax.random.PRNGKey(42))


@pytest.fixture(scope="module")
def batch(params):
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, CFG.vocab_size)
    mask = jnp.ones((B, T)).at[:, 0].set(0.0).at[:, :8].set(0.0)  # prompt of 8
    lp, _ = model.token_logprobs(CFG, params, tokens)
    return tokens, mask, lp


def default_hyper(**kw):
    h = {"lr": 1e-3, "beta1": 0.9, "beta2": 0.999, "adam_eps": 1e-8,
         "clip_eps": 0.2, "tau_or_beta": 1.0, "mu": 0.1, "kl_coef": 0.0}
    h.update(kw)
    return jnp.array(list(h.values()), jnp.float32)


def zeros_like_tree(t):
    return jax.tree_util.tree_map(jnp.zeros_like, t)


class TestGRPO:
    def test_on_policy_loss_gradient_direction(self, params, batch):
        """A step with +adv on seq 0 / -adv on seq 1 must raise lp(seq0) and
        lower lp(seq1)."""
        tokens, mask, lp = batch
        adv = jnp.array([2.0, -2.0, 0.0, 0.0])
        step = losses.make_train_step(CFG, "grpo")
        m, v = zeros_like_tree(params), zeros_like_tree(params)
        hyper = default_hyper(lr=5e-3)
        p2, *_ = jax.jit(step)(params, m, v, jnp.float32(1), hyper, tokens, mask, adv, lp)
        lp2, _ = model.token_logprobs(CFG, p2, tokens)
        seq = lambda l: jnp.sum(l * mask, axis=1)
        assert float(seq(lp2)[0]) > float(seq(lp)[0])
        assert float(seq(lp2)[1]) < float(seq(lp)[1])

    def test_on_policy_zero_mean_adv_gives_zero_pg(self, params, batch):
        """At ratio==1, pg loss = -mean(adv) over mask; group-centred adv -> 0."""
        tokens, mask, lp = batch
        adv = jnp.array([1.0, -1.0, 0.5, -0.5])
        loss, metrics = losses.grpo_loss(CFG, params, default_hyper(), tokens, mask, adv, lp)
        assert abs(float(metrics[2])) < 1e-5  # KL(new||old) == 0 on-policy
        assert abs(float(loss)) < 1e-4

    def test_clipping_limits_offpolicy_update(self, params, batch):
        tokens, mask, lp = batch
        adv = jnp.ones((B,))
        # very off-policy old_lp -> ratios far from 1 -> clip_frac high
        old_lp = lp - 2.0 * mask
        _, metrics = losses.grpo_loss(CFG, params, default_hyper(), tokens, mask, adv, old_lp)
        assert float(metrics[3]) > 0.9  # clip_frac

    def test_metrics_finite(self, params, batch):
        tokens, mask, lp = batch
        adv = jnp.array([1.0, -1.0, 2.0, 0.0])
        _, metrics = losses.grpo_loss(CFG, params, default_hyper(), tokens, mask, adv, lp)
        assert bool(jnp.all(jnp.isfinite(metrics)))


class TestSFT:
    def test_nll_decreases(self, params, batch):
        tokens, mask, lp = batch
        step = losses.make_train_step(CFG, "sft")
        m, v = zeros_like_tree(params), zeros_like_tree(params)
        p, hyper = params, default_hyper(lr=5e-3)
        nll0 = -float(losses.masked_mean(lp, mask))
        for i in range(3):
            p, m, v, metrics = jax.jit(step)(p, m, v, jnp.float32(i + 1), hyper, tokens, mask)
        lp2, _ = model.token_logprobs(CFG, p, tokens)
        assert -float(losses.masked_mean(lp2, mask)) < nll0


class TestDummyLearning:
    """lr=0 'dummy learning' (Tables 1-2): full compute, frozen params."""

    @pytest.mark.parametrize("alg,group", [("grpo", 1), ("sft", 1), ("opmd_simple", 4)])
    def test_lr0_freezes_params(self, params, batch, alg, group):
        tokens, mask, lp = batch
        step = losses.make_train_step(CFG, alg, group_size=group)
        m, v = zeros_like_tree(params), zeros_like_tree(params)
        hyper = default_hyper(lr=0.0)
        data = {
            "grpo": (tokens, mask, jnp.ones((B,)), lp),
            "sft": (tokens, mask),
            "opmd_simple": (tokens, mask, jnp.array([1.0, 0.0, 0.5, 0.2]), lp),
        }[alg]
        p2, m2, _, metrics = jax.jit(step)(params, m, v, jnp.float32(1), hyper, *data)
        for k in params:
            assert float(jnp.max(jnp.abs(p2[k] - params[k]))) == 0.0
        assert bool(jnp.all(jnp.isfinite(metrics)))


class TestDPO:
    def test_margin_improves(self, params):
        tc = jax.random.randint(jax.random.PRNGKey(2), (2, T), 0, CFG.vocab_size)
        tr = jax.random.randint(jax.random.PRNGKey(3), (2, T), 0, CFG.vocab_size)
        mask = jnp.ones((2, T)).at[:, 0].set(0.0)
        lp_c, _ = model.token_logprobs(CFG, params, tc)
        lp_r, _ = model.token_logprobs(CFG, params, tr)
        ref_c = jnp.sum(lp_c * mask, axis=1)
        ref_r = jnp.sum(lp_r * mask, axis=1)
        step = losses.make_train_step(CFG, "dpo")
        m, v = zeros_like_tree(params), zeros_like_tree(params)
        hyper = default_hyper(lr=5e-3, tau_or_beta=0.5)
        p = params
        for i in range(3):
            p, m, v, metrics = jax.jit(step)(
                p, m, v, jnp.float32(i + 1), hyper, tc, mask, tr, mask, ref_c, ref_r
            )
        lp_c2, _ = model.token_logprobs(CFG, p, tc)
        lp_r2, _ = model.token_logprobs(CFG, p, tr)
        margin = jnp.sum(lp_c2 * mask, axis=1) - ref_c - (jnp.sum(lp_r2 * mask, axis=1) - ref_r)
        assert float(jnp.min(margin)) > 0.0

    def test_zero_margin_gives_log2(self, params):
        """Identical chosen/rejected -> loss == log 2."""
        tc = jax.random.randint(jax.random.PRNGKey(4), (2, T), 0, CFG.vocab_size)
        mask = jnp.ones((2, T)).at[:, 0].set(0.0)
        lp, _ = model.token_logprobs(CFG, params, tc)
        ref = jnp.sum(lp * mask, axis=1)
        loss, _ = losses.dpo_loss(CFG, params, default_hyper(tau_or_beta=0.5), tc, mask, tc, mask, ref, ref)
        assert abs(float(loss) - float(jnp.log(2.0))) < 1e-5


class TestMIX:
    def test_mu_zero_equals_grpo(self, params, batch):
        tokens, mask, lp = batch
        adv = jnp.array([1.0, -1.0, 0.5, -0.5])
        is_expert = jnp.zeros((B,))
        hyper = default_hyper(mu=0.0)
        l_mix, _ = losses.mix_loss(CFG, params, hyper, tokens, mask, adv, lp, is_expert)
        l_grpo, _ = losses.grpo_loss(CFG, params, hyper, tokens, mask, adv, lp)
        assert abs(float(l_mix) - float(l_grpo)) < 1e-5

    def test_mu_one_equals_sft_on_experts(self, params, batch):
        tokens, mask, lp = batch
        adv = jnp.zeros((B,))
        is_expert = jnp.ones((B,))
        hyper = default_hyper(mu=1.0)
        l_mix, _ = losses.mix_loss(CFG, params, hyper, tokens, mask, adv, lp, is_expert)
        l_sft, _ = losses.sft_loss(CFG, params, hyper, tokens, mask)
        assert abs(float(l_mix) - float(l_sft)) < 1e-5

    def test_expert_frac_metric(self, params, batch):
        tokens, mask, lp = batch
        is_expert = jnp.array([1.0, 0.0, 1.0, 0.0])
        _, metrics = losses.mix_loss(
            CFG, params, default_hyper(), tokens, mask, jnp.zeros((B,)), lp, is_expert
        )
        assert abs(float(metrics[6]) - 0.5) < 1e-6


class TestOPMD:
    """Appendix A: the three OPMD variants."""

    def test_pairwise_identity(self):
        """K*sum(a^2) - (sum a)^2 == sum_{i<j} (a_i - a_j)^2."""
        a = jax.random.normal(jax.random.PRNGKey(0), (7,))
        k = 7
        lhs = k * jnp.sum(a**2) - jnp.sum(a) ** 2
        rhs = sum(float((a[i] - a[j]) ** 2) for i in range(k) for j in range(i + 1, k))
        assert abs(float(lhs) - rhs) < 1e-4

    def test_simple_opmd_equals_scaled_pg_at_theta_t(self, params, batch):
        """Appendix A.3: at theta=theta_t the OPMD-simple gradient equals the
        group-baseline policy gradient scaled by 1/(1+tau)."""
        tokens, mask, lp = batch
        rewards = jnp.array([1.0, 0.0, 0.5, 0.25])
        tau = 1.0

        def opmd(p):
            return losses.opmd_simple_loss(
                CFG, p, default_hyper(tau_or_beta=tau), tokens, mask, rewards, lp, group_size=4
            )[0]

        def vanilla_pg(p):
            lp_new, _ = model.token_logprobs(CFG, p, tokens)
            seq_lp = jnp.sum(lp_new * mask, axis=1)
            adv = rewards - jnp.mean(rewards)
            return -jnp.mean(adv * seq_lp)

        g1 = jax.grad(opmd)(params)
        g2 = jax.grad(vanilla_pg)(params)
        for k in params:
            assert float(jnp.max(jnp.abs(g1[k] * (1.0 + tau) - g2[k]))) < 1e-5

    def test_kimi_opmd_zero_loss_at_consistency(self, params, batch):
        """If rewards are constant within the group and theta==theta_t, the
        residual reduces to r - logZ = 0 (logZ = r for constant rewards)."""
        tokens, mask, lp = batch
        rewards = jnp.full((B,), 0.7)
        loss, _ = losses.opmd_kimi_loss(
            CFG, params, default_hyper(tau_or_beta=1.0), tokens, mask, rewards, lp, group_size=4
        )
        assert abs(float(loss)) < 1e-6

    def test_pairwise_opmd_learning_direction(self, params, batch):
        tokens, mask, lp = batch
        rewards = jnp.array([1.0, 0.0, 0.0, 0.0])
        step = losses.make_train_step(CFG, "opmd_pairwise", group_size=4)
        m, v = zeros_like_tree(params), zeros_like_tree(params)
        p2, *_ = jax.jit(step)(
            params, m, v, jnp.float32(1), default_hyper(lr=5e-3), tokens, mask, rewards, lp
        )
        lp2, _ = model.token_logprobs(CFG, p2, tokens)
        seq = lambda l: jnp.sum(l * mask, axis=1)
        # the rewarded sequence's logprob should rise relative to the others
        delta = seq(lp2) - seq(lp)
        assert float(delta[0]) > float(jnp.max(delta[1:]))

    @pytest.mark.parametrize("alg", ["opmd_kimi", "opmd_pairwise", "opmd_simple"])
    def test_all_variants_finite(self, params, batch, alg):
        tokens, mask, lp = batch
        rewards = jnp.array([1.0, -1.0, 0.5, 0.0])
        fn = losses.ALGORITHMS[alg][0]
        loss, metrics = fn(CFG, params, default_hyper(), tokens, mask, rewards, lp, group_size=4)
        assert bool(jnp.isfinite(loss))
        assert bool(jnp.all(jnp.isfinite(metrics)))
