"""L2 model correctness: shapes, decode/prefill consistency, logprob semantics."""

import functools

import jax
import jax.numpy as jnp
import pytest

from compile import model
from compile.model import PRESETS

CFG = PRESETS["tiny"]


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG, jax.random.PRNGKey(0))


def toks(key, b, t):
    return jax.random.randint(jax.random.PRNGKey(key), (b, t), 0, CFG.vocab_size)


class TestForward:
    def test_hidden_shape(self, params):
        h = model.forward_hidden(CFG, params, toks(0, 2, 32))
        assert h.shape == (2, 32, CFG.d_model)
        assert bool(jnp.all(jnp.isfinite(h)))

    def test_param_count_matches_spec(self, params):
        total = sum(p.size for p in params.values())
        assert total == CFG.param_count()

    def test_param_order_is_sorted(self, params):
        leaves = jax.tree_util.tree_flatten_with_path(params)[0]
        names = [jax.tree_util.keystr(p, simple=True, separator="/") for p, _ in leaves]
        assert names == sorted(names)
        assert names == [n for n, _, _ in model.param_shapes(CFG)]

    def test_causality_of_forward(self, params):
        """Changing token t must not change hidden states before t."""
        t1 = toks(1, 1, 32)
        t2 = t1.at[0, 20].set((t1[0, 20] + 1) % CFG.vocab_size)
        h1 = model.forward_hidden(CFG, params, t1)
        h2 = model.forward_hidden(CFG, params, t2)
        assert jnp.max(jnp.abs(h1[:, :20] - h2[:, :20])) < 1e-5
        assert jnp.max(jnp.abs(h1[:, 20:] - h2[:, 20:])) > 1e-4


class TestLogprobs:
    def test_shapes_and_first_column_zero(self, params):
        lp, ent = model.token_logprobs(CFG, params, toks(2, 4, 64))
        assert lp.shape == (4, 64) and ent.shape == (4, 64)
        assert jnp.max(jnp.abs(lp[:, 0])) == 0.0

    def test_logprobs_nonpositive(self, params):
        lp, _ = model.token_logprobs(CFG, params, toks(3, 2, 32))
        assert bool(jnp.all(lp <= 1e-6))

    def test_matches_naive_softmax(self, params):
        tokens = toks(4, 2, 32)
        lp, _ = model.token_logprobs(CFG, params, tokens)
        h = model.forward_hidden(CFG, params, tokens)
        logits = h @ params["unembed"]
        naive = jax.nn.log_softmax(logits, axis=-1)
        for b in range(2):
            for j in range(1, 32):
                assert abs(float(lp[b, j]) - float(naive[b, j - 1, tokens[b, j]])) < 1e-4

    def test_entropy_no_gradient(self, params):
        tokens = toks(5, 2, 32)

        def f(p):
            _, ent = model.token_logprobs(CFG, p, tokens)
            return jnp.sum(ent)

        g = jax.grad(f)(params)
        assert all(float(jnp.max(jnp.abs(v))) == 0.0 for v in jax.tree_util.tree_leaves(g))


class TestGeneration:
    def test_prefill_decode_consistency(self, params):
        """Greedy path through prefill+decode == full forward logits."""
        b, tp, tc = 4, 32, 64
        tokens = toks(6, b, tp)
        lens = jnp.array([5, 9, 12, 3], jnp.int32)
        last_logits, kc, vc = model.prefill(CFG, params, tokens, lens, tc)
        h = model.forward_hidden(CFG, params, tokens)
        for i in range(b):
            expected = h[i, lens[i] - 1] @ params["unembed"]
            assert jnp.max(jnp.abs(last_logits[i] - expected)) < 1e-4

    def test_multistep_decode_matches_forward(self, params):
        """Decode 5 tokens sequentially; logits match a fresh full forward."""
        b, tp, tc = 4, 32, 64
        prompt = toks(7, b, tp)
        lens = jnp.array([4, 7, 10, 6], jnp.int32)
        _, kc, vc = model.prefill(CFG, params, prompt, lens, tc)
        seq = prompt
        pos = lens
        decode = functools.partial(model.decode_step, CFG)
        new_tokens = jax.random.randint(jax.random.PRNGKey(8), (5, b), 0, CFG.vocab_size)
        for s in range(5):
            nt = new_tokens[s]
            logits, kc, vc = decode(params, kc, vc, nt, pos)
            for i in range(b):
                seq = seq.at[i, pos[i]].set(nt[i])
            # reference: full forward over the written sequence
            h = model.forward_hidden(CFG, params, seq)
            for i in range(b):
                expected = h[i, pos[i]] @ params["unembed"]
                assert jnp.max(jnp.abs(logits[i] - expected)) < 2e-4, f"step {s} seq {i}"
            pos = pos + 1

    def test_per_sequence_positions_independent(self, params):
        """Continuous batching: sequences at different positions don't interfere."""
        b, tp, tc = 4, 32, 64
        prompt = toks(9, b, tp)
        lens = jnp.array([3, 30, 15, 8], jnp.int32)
        _, kc, vc = model.prefill(CFG, params, prompt, lens, tc)
        nt = jnp.array([1, 2, 3, 4], jnp.int32)
        logits, _, _ = model.decode_step(CFG, params, kc, vc, nt, lens)
        assert bool(jnp.all(jnp.isfinite(logits)))


class TestEmbed:
    def test_shape_and_norm(self, params):
        emb = model.pooled_embed(CFG, params, toks(10, 4, 64), jnp.ones((4, 64)))
        assert emb.shape == (4, CFG.d_model)
        norms = jnp.linalg.norm(emb, axis=-1)
        assert jnp.max(jnp.abs(norms - 1.0)) < 1e-5

    def test_mask_excludes_positions(self, params):
        tokens = toks(11, 2, 64)
        mask_full = jnp.ones((2, 64))
        mask_half = mask_full.at[:, 32:].set(0.0)
        e1 = model.pooled_embed(CFG, params, tokens, mask_half)
        # changing masked-out tokens must not change the embedding
        tokens2 = tokens.at[:, 40:].set(0)
        e2 = model.pooled_embed(CFG, params, tokens2, mask_half)
        # (hidden states at masked positions still differ, but causality means
        # positions < 32 are unaffected by edits at >= 40)
        assert jnp.max(jnp.abs(e1 - e2)) < 1e-5

    def test_identical_sequences_have_cosine_one(self, params):
        tokens = jnp.tile(toks(12, 1, 64), (4, 1))
        emb = model.pooled_embed(CFG, params, tokens, jnp.ones((4, 64)))
        sims = emb @ emb.T
        assert jnp.min(sims) > 1.0 - 1e-5


class TestPresets:
    @pytest.mark.parametrize("name", ["tiny", "small", "base", "large"])
    def test_preset_sanity(self, name):
        cfg = PRESETS[name]
        assert cfg.d_model % cfg.n_heads == 0
        assert cfg.head_dim % 2 == 0  # RoPE needs even head dim
        assert cfg.vocab_size % 128 == 0  # fused-CE vocab tile
        assert cfg.max_seq % 32 == 0  # attention q/k tiles

    def test_large_is_roughly_100m(self):
        assert 80e6 < PRESETS["large"].param_count() < 150e6
