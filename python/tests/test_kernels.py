"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes (and block sizes) as required for the kernel
contract; fixed-seed regression cases pin exact tolerances.
"""

import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.adam import adam_update_flat, adam_update_tree
from compile.kernels.attention import flash_attention
from compile.kernels.fused_ce import fused_ce, fused_ce_grads

ATOL = 2e-5


def rand(key, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------------------
# flash attention


class TestFlashAttention:
    @settings(max_examples=12, deadline=None)
    @given(
        b=st.sampled_from([1, 2, 3]),
        h=st.sampled_from([1, 2, 4]),
        t=st.sampled_from([32, 64, 128]),
        dh=st.sampled_from([8, 16, 32]),
        causal=st.booleans(),
    )
    def test_matches_ref(self, b, h, t, dh, causal):
        q, k, v = (rand(i + 17 * b + t, (b, h, t, dh)) for i in range(3))
        out = flash_attention(q, k, v, causal)
        expected = ref.ref_attention(q, k, v, causal)
        assert jnp.max(jnp.abs(out - expected)) < ATOL

    @pytest.mark.parametrize("block_q,block_k", [(16, 16), (32, 16), (16, 32), (64, 64)])
    def test_block_size_invariance(self, block_q, block_k):
        q, k, v = (rand(i, (2, 2, 64, 16)) for i in range(3))
        out = flash_attention(q, k, v, True, block_q, block_k)
        expected = ref.ref_attention(q, k, v, True)
        assert jnp.max(jnp.abs(out - expected)) < ATOL

    def test_gradients_match_ref(self):
        q, k, v = (rand(i + 5, (2, 2, 32, 16)) for i in range(3))
        f = lambda *a: jnp.sum(flash_attention(*a) ** 2)
        fr = lambda *a: jnp.sum(ref.ref_attention(*a) ** 2)
        grads = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
        grads_ref = jax.grad(fr, argnums=(0, 1, 2))(q, k, v)
        for g, gr in zip(grads, grads_ref):
            assert jnp.max(jnp.abs(g - gr)) < 1e-4

    def test_causality(self):
        """Perturbing future K/V must not change past outputs."""
        q, k, v = (rand(i + 9, (1, 1, 64, 16)) for i in range(3))
        out1 = flash_attention(q, k, v)
        k2 = k.at[:, :, 40:, :].add(100.0)
        v2 = v.at[:, :, 40:, :].add(100.0)
        out2 = flash_attention(q, k2, v2)
        assert jnp.max(jnp.abs(out1[:, :, :40] - out2[:, :, :40])) < 1e-6
        assert jnp.max(jnp.abs(out1[:, :, 41:] - out2[:, :, 41:])) > 1e-3

    def test_softmax_stability_large_logits(self):
        q, k, v = (rand(i, (1, 1, 32, 8), scale=30.0) for i in range(3))
        out = flash_attention(q, k, v)
        assert bool(jnp.all(jnp.isfinite(out)))
        expected = ref.ref_attention(q, k, v)
        assert jnp.max(jnp.abs(out - expected)) < 1e-3

    def test_under_jit_and_vmap_compat(self):
        q, k, v = (rand(i, (2, 2, 32, 16)) for i in range(3))
        out = jax.jit(lambda a, b, c: flash_attention(a, b, c))(q, k, v)
        expected = ref.ref_attention(q, k, v)
        assert jnp.max(jnp.abs(out - expected)) < ATOL


# ---------------------------------------------------------------------------
# fused CE


class TestFusedCE:
    @settings(max_examples=12, deadline=None)
    @given(
        n=st.sampled_from([32, 64, 96]),
        d=st.sampled_from([16, 32, 64]),
        v=st.sampled_from([128, 256, 512]),
        scale=st.sampled_from([0.1, 1.0, 5.0]),
    )
    def test_matches_ref(self, n, d, v, scale):
        h = rand(n + d, (n, d), scale)
        w = rand(v, (d, v), 0.1)
        t = jax.random.randint(jax.random.PRNGKey(n * v), (n,), 0, v)
        lp, lse, ent = fused_ce(h, w, t)
        lp_r, lse_r, ent_r = ref.ref_fused_ce(h, w, t)
        assert jnp.max(jnp.abs(lp - lp_r)) < ATOL * max(1.0, scale)
        assert jnp.max(jnp.abs(lse - lse_r)) < ATOL * max(1.0, scale)
        assert jnp.max(jnp.abs(ent - ent_r)) < 1e-3 * max(1.0, scale)

    def test_logprobs_are_normalized(self):
        """exp(lp) summed over all possible targets must be 1."""
        n, d, v = 4, 16, 128
        h = rand(0, (n, d))
        w = rand(1, (d, v), 0.1)
        total = jnp.zeros((n,))
        for tgt in range(v):
            lp, _, _ = fused_ce(h, w, jnp.full((n,), tgt, jnp.int32))
            total = total + jnp.exp(lp)
        assert jnp.max(jnp.abs(total - 1.0)) < 1e-3

    def test_grads_match_analytic(self):
        n, d, v = 64, 32, 256
        h = rand(3, (n, d))
        w = rand(4, (d, v), 0.1)
        t = jax.random.randint(jax.random.PRNGKey(5), (n,), 0, v)
        g = rand(6, (n,))

        def loss(h_, w_):
            lp, _, _ = fused_ce(h_, w_, t)
            return jnp.sum(lp * g)

        dh, dw = jax.grad(loss, argnums=(0, 1))(h, w)
        dh_r, dw_r = ref.ref_fused_ce_grads(h, w, t, g)
        assert jnp.max(jnp.abs(dh - dh_r)) < 1e-4
        assert jnp.max(jnp.abs(dw - dw_r)) < 1e-4

    def test_direct_grad_kernel(self):
        n, d, v = 32, 16, 128
        h = rand(7, (n, d))
        w = rand(8, (d, v), 0.1)
        t = jax.random.randint(jax.random.PRNGKey(9), (n,), 0, v)
        g = rand(10, (n,))
        _, lse, _ = fused_ce(h, w, t)
        dh, dw = fused_ce_grads(h, w, t, lse, g)
        dh_r, dw_r = ref.ref_fused_ce_grads(h, w, t, g)
        assert jnp.max(jnp.abs(dh - dh_r)) < 1e-4
        assert jnp.max(jnp.abs(dw - dw_r)) < 1e-4

    def test_entropy_nonnegative_and_bounded(self):
        n, d, v = 32, 16, 256
        h = rand(11, (n, d))
        w = rand(12, (d, v), 0.05)
        t = jnp.zeros((n,), jnp.int32)
        _, _, ent = fused_ce(h, w, t)
        assert bool(jnp.all(ent >= -1e-4))
        assert bool(jnp.all(ent <= jnp.log(v) + 1e-4))

    def test_metric_cotangents_ignored(self):
        """lse/ent are metrics; grads must flow only through lp."""
        n, d, v = 32, 16, 128
        h = rand(13, (n, d))
        w = rand(14, (d, v), 0.1)
        t = jax.random.randint(jax.random.PRNGKey(15), (n,), 0, v)

        def loss(h_):
            lp, lse, ent = fused_ce(h_, w, t)
            return jnp.sum(lp) + 0.0 * jnp.sum(lse) + 0.0 * jnp.sum(ent)

        dh = jax.grad(loss)(h)
        dh_r, _ = ref.ref_fused_ce_grads(h, w, t, jnp.ones((n,)))
        assert jnp.max(jnp.abs(dh - dh_r)) < 1e-4


# ---------------------------------------------------------------------------
# fused Adam


class TestFusedAdam:
    @settings(max_examples=10, deadline=None)
    @given(
        n=st.integers(min_value=1, max_value=2000),
        step=st.integers(min_value=1, max_value=100),
        lr=st.sampled_from([0.0, 1e-4, 1e-2]),
    )
    def test_matches_ref(self, n, step, lr):
        p = rand(n, (n,))
        g = rand(n + 1, (n,))
        m = rand(n + 2, (n,), 0.1)
        v = jnp.abs(rand(n + 3, (n,), 0.1))
        b1, b2, eps = 0.9, 0.999, 1e-8
        bc1, bc2 = 1 - b1**step, 1 - b2**step
        hyper = jnp.array([lr, b1, b2, eps, bc1, bc2], jnp.float32)
        p2, m2, v2 = adam_update_flat(p, g, m, v, hyper)
        pr, mr, vr = ref.ref_adam(p, g, m, v, lr, b1, b2, eps, bc1, bc2)
        assert jnp.max(jnp.abs(p2 - pr)) < 1e-5
        assert jnp.max(jnp.abs(m2 - mr)) < 1e-5
        assert jnp.max(jnp.abs(v2 - vr)) < 1e-5

    def test_lr_zero_is_identity_on_params(self):
        """lr=0 dummy learning (Tables 1-2) must leave params untouched."""
        p = rand(1, (257,))
        g = rand(2, (257,))
        m = jnp.zeros_like(p)
        v = jnp.zeros_like(p)
        hyper = jnp.array([0.0, 0.9, 0.999, 1e-8, 0.1, 1e-3], jnp.float32)
        p2, m2, v2 = adam_update_flat(p, g, m, v, hyper)
        assert jnp.max(jnp.abs(p2 - p)) == 0.0
        # but optimizer state still advances (as in the real system)
        assert jnp.max(jnp.abs(m2)) > 0.0

    def test_tree_update_matches_flat(self):
        tree_p = {"a": rand(1, (40, 3)), "b": rand(2, (7,))}
        tree_g = {"a": rand(3, (40, 3)), "b": rand(4, (7,))}
        tree_m = jax.tree_util.tree_map(jnp.zeros_like, tree_p)
        tree_v = jax.tree_util.tree_map(jnp.zeros_like, tree_p)
        hyper = jnp.array([1e-3, 0.9, 0.999, 1e-8, 0.1, 1e-3], jnp.float32)
        p2, m2, v2 = adam_update_tree(tree_p, tree_g, tree_m, tree_v, hyper)
        for k in tree_p:
            pr, mr, vr = ref.ref_adam(
                tree_p[k], tree_g[k], tree_m[k], tree_v[k], 1e-3, 0.9, 0.999, 1e-8, 0.1, 1e-3
            )
            assert jnp.max(jnp.abs(p2[k] - pr)) < 1e-6
            assert jnp.max(jnp.abs(m2[k] - mr)) < 1e-6
            assert jnp.max(jnp.abs(v2[k] - vr)) < 1e-6
