"""AOT contract tests: manifest <-> HLO consistency (the Rust-facing contract)."""

import json
import os
import re

import pytest

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ART_DIR, "manifest.json")

pytestmark = pytest.mark.skipif(
    not os.path.exists(MANIFEST), reason="artifacts not built (run `make artifacts`)"
)


@pytest.fixture(scope="module")
def manifest():
    with open(MANIFEST) as f:
        return json.load(f)


def test_manifest_has_models_and_artifacts(manifest):
    assert "tiny" in manifest["models"]
    assert len(manifest["artifacts"]) >= 10
    assert manifest["hyper_slots"][0] == "lr"


def test_all_artifact_files_exist(manifest):
    for name, entry in manifest["artifacts"].items():
        path = os.path.join(ART_DIR, entry["file"])
        assert os.path.exists(path), f"missing {path}"
        assert os.path.getsize(path) > 1000


def test_param_leaves_match_model_spec(manifest):
    from compile import model

    for preset, entry in manifest["models"].items():
        cfg = model.PRESETS[preset]
        expected = model.param_shapes(cfg)
        assert len(entry["params"]) == len(expected)
        for rec, (name, shape, std) in zip(entry["params"], expected):
            assert rec["name"] == name
            assert tuple(rec["shape"]) == tuple(shape)
            assert rec["init_std"] == std


def test_hlo_parameter_count_matches_manifest(manifest):
    """The number of ENTRY parameters in each HLO must equal manifest inputs."""
    for name, entry in manifest["artifacts"].items():
        path = os.path.join(ART_DIR, entry["file"])
        with open(path) as f:
            text = f.read()
        entry_match = re.search(r"ENTRY[^{]*\{(.*?)\n\}", text, re.S)
        assert entry_match, f"no ENTRY computation in {name}"
        n_params = len(re.findall(r"=\s*\S+\s+parameter\(\d+\)", entry_match.group(1)))
        assert n_params == len(entry["inputs"]), (
            f"{name}: {n_params} HLO params vs {len(entry['inputs'])} manifest inputs"
        )


def test_train_artifacts_roundtrip_param_roles(manifest):
    for name, entry in manifest["artifacts"].items():
        if entry["kind"] != "train":
            continue
        n_leaves = len(manifest["models"][entry["model"]]["params"])
        roles = [i["role"] for i in entry["inputs"]]
        assert roles.count("param") == n_leaves
        assert roles.count("opt_m") == n_leaves
        assert roles.count("opt_v") == n_leaves
        assert roles.count("step") == 1
        assert roles.count("hyper") == 1
        out_roles = [o["role"] for o in entry["outputs"]]
        assert out_roles.count("param") == n_leaves
        assert out_roles.count("metrics") == 1
        assert len(entry["metrics"]) == 9  # 8 loss metrics + grad_norm

    # param input shapes must match the model param table, in order
    entry = next(e for e in manifest["artifacts"].values() if e["kind"] == "train")
    model_params = manifest["models"][entry["model"]]["params"]
    param_inputs = [i for i in entry["inputs"] if i["role"] == "param"]
    for mp, pi in zip(model_params, param_inputs):
        assert pi["shape"] == mp["shape"]


def test_data_input_names_recorded(manifest):
    for name, entry in manifest["artifacts"].items():
        if entry["kind"] != "train":
            continue
        data_inputs = [i for i in entry["inputs"] if i["role"] == "data"]
        assert len(data_inputs) == len(entry["data_inputs"])


def test_hlo_is_text_not_proto(manifest):
    """Guard against regressions to .serialize() (64-bit-id protos)."""
    any_file = next(iter(manifest["artifacts"].values()))["file"]
    with open(os.path.join(ART_DIR, any_file), "rb") as f:
        head = f.read(64)
    assert b"HloModule" in head
