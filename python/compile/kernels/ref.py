"""Pure-jnp oracles for the Pallas kernels (L1 correctness ground truth).

Every Pallas kernel in this package has a reference implementation here,
written with the most straightforward jnp formulation (materializing the
full score matrix / full logits). pytest pins kernel == ref to tight
tolerances across shape/dtype sweeps; the kernels exist to avoid these
materializations, not to change the math.
"""

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def ref_attention(q: jax.Array, k: jax.Array, v: jax.Array, causal: bool = True) -> jax.Array:
    """Plain softmax attention. q, k, v: [B, H, T, dh] -> [B, H, T, dh]."""
    dh = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, dtype=q.dtype))
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    if causal:
        t = q.shape[2]
        mask = jnp.tril(jnp.ones((t, t), dtype=bool))
        scores = jnp.where(mask[None, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


def ref_fused_ce(h: jax.Array, w: jax.Array, targets: jax.Array):
    """Unembed + log-softmax + target gather, materializing full logits.

    h: [N, D], w: [D, V], targets: [N] int32.
    Returns (target_logprob [N], logsumexp [N], entropy [N]).
    """
    logits = h @ w  # [N, V]
    lse = jax.scipy.special.logsumexp(logits, axis=-1)  # [N]
    target_logit = jnp.take_along_axis(logits, targets[:, None], axis=-1)[:, 0]
    lp = target_logit - lse
    probs = jax.nn.softmax(logits, axis=-1)
    entropy = lse - jnp.sum(probs * logits, axis=-1)
    return lp, lse, entropy


def ref_fused_ce_grads(h: jax.Array, w: jax.Array, targets: jax.Array, g_lp: jax.Array):
    """Analytic grads of sum(g_lp * target_logprob) wrt h and w."""
    logits = h @ w
    probs = jax.nn.softmax(logits, axis=-1)  # [N, V]
    onehot = jax.nn.one_hot(targets, w.shape[1], dtype=h.dtype)
    dlogits = g_lp[:, None] * (onehot - probs)  # [N, V]
    dh = dlogits @ w.T
    dw = h.T @ dlogits
    return dh, dw


def ref_adam(p, g, m, v, lr, b1, b2, eps, bc1, bc2):
    """One Adam step with externally supplied bias corrections.

    bc1 = 1 - b1**t, bc2 = 1 - b2**t.  All args are arrays or scalars.
    Returns (p', m', v').
    """
    m_new = b1 * m + (1.0 - b1) * g
    v_new = b2 * v + (1.0 - b2) * g * g
    m_hat = m_new / bc1
    v_hat = v_new / bc2
    p_new = p - lr * m_hat / (jnp.sqrt(v_hat) + eps)
    return p_new, m_new, v_new
