"""Pallas vocab-tiled fused unembed + log-softmax kernel (L1 hot-spot).

The RFT training memory hot-spot is the logits tensor: B*T*V floats that a
naive implementation materializes in HBM three times (forward logits,
softmax, backward dlogits).  For the `large` preset (B=8, T=512, V=16384)
that is 256 MiB per materialization.  This kernel computes per-token target
log-probabilities, logsumexp and entropy in one pass that tiles the vocab
dimension: a hidden-row tile [Bn, D] and a weight tile [D, Bv] meet in VMEM,
and only O(Bn) statistics survive.  Backward recomputes the per-tile softmax
from the saved logsumexp (flash-attention-style rematerialization) in two
Pallas kernels: one accumulating dH over vocab tiles (row-parallel grid),
one accumulating dW over row tiles (vocab-parallel grid) so that every
output block is revisited only by consecutive grid steps — the layout a
real TPU requires for accumulation.

VMEM per grid step (f32, base preset D=512, Bn=64, Bv=512): h-tile 128 KiB +
w-tile 1 MiB + logits tile 128 KiB ≈ 1.3 MiB.  MXU work is the [Bn,D]x[D,Bv]
matmul; VPU work is O(Bn*Bv) exp/max — compute intensity identical to the
fused kernels in production LM stacks.

Entropy and logsumexp are produced as metrics; the custom_vjp deliberately
propagates gradients only through the target log-probability (L2 stop-grads
the metric outputs), which keeps the backward at exactly two recompute
matmuls per tile.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 32
DEFAULT_BLOCK_V = 128


def _fwd_kernel(h_ref, w_ref, t_ref, lp_ref, lse_ref, ent_ref, *, block_v: int):
    # h_ref: [Bn, D]; w_ref: [D, V]; t_ref: [Bn]; outputs: [Bn]
    block_n = h_ref.shape[0]
    v_total = w_ref.shape[1]
    n_v = v_total // block_v
    h = h_ref[:, :]  # [Bn, D]
    targets = t_ref[:]  # [Bn] int32

    def body(jv, carry):
        m_prev, l_prev, s_prev, t_prev = carry
        w_tile = w_ref[:, pl.dslice(jv * block_v, block_v)]  # [D, Bv]
        x = h @ w_tile  # [Bn, Bv]
        v_idx = jv * block_v + jax.lax.broadcasted_iota(jnp.int32, (block_n, block_v), 1)
        # Online max / denominator / x-weighted sum (for entropy).
        m_cur = jnp.max(x, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(x - m_new[:, None])
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        s_new = s_prev * alpha + jnp.sum(x * p, axis=-1)
        # Exactly one tile contains each row's target column.
        hit = v_idx == targets[:, None]
        t_new = t_prev + jnp.sum(jnp.where(hit, x, 0.0), axis=-1)
        return m_new, l_new, s_new, t_new

    m0 = jnp.full((block_n,), -1e30, dtype=jnp.float32)
    l0 = jnp.zeros((block_n,), dtype=jnp.float32)
    s0 = jnp.zeros((block_n,), dtype=jnp.float32)
    t0 = jnp.zeros((block_n,), dtype=jnp.float32)
    m, l, s, t = jax.lax.fori_loop(0, n_v, body, (m0, l0, s0, t0))
    lse = m + jnp.log(l)
    lp_ref[:] = t - lse
    lse_ref[:] = lse
    # H = lse - E_p[x]; E_p[x] = s / l (s, l share the same max-shift).
    ent_ref[:] = lse - s / l


def _dh_kernel(h_ref, w_ref, t_ref, lse_ref, g_ref, dh_ref, *, block_v: int):
    # Row-parallel: grid over row tiles, loop vocab tiles, accumulate dH.
    # dH = g * (w[:, target] - W @ p)  per row.
    block_n = h_ref.shape[0]
    d = h_ref.shape[1]
    v_total = w_ref.shape[1]
    n_v = v_total // block_v
    h = h_ref[:, :]
    targets = t_ref[:]
    lse = lse_ref[:]
    g = g_ref[:]

    def body(jv, acc):
        w_tile = w_ref[:, pl.dslice(jv * block_v, block_v)]  # [D, Bv]
        x = h @ w_tile  # [Bn, Bv]
        p = jnp.exp(x - lse[:, None])
        v_idx = jv * block_v + jax.lax.broadcasted_iota(jnp.int32, (block_n, block_v), 1)
        hit = (v_idx == targets[:, None]).astype(jnp.float32)
        coeff = g[:, None] * (hit - p)  # [Bn, Bv]
        return acc + coeff @ w_tile.T  # [Bn, D]

    acc0 = jnp.zeros((block_n, d), dtype=jnp.float32)
    dh_ref[:, :] = jax.lax.fori_loop(0, n_v, body, acc0)


def _dw_kernel(h_ref, w_ref, t_ref, lse_ref, g_ref, dw_ref, *, block_n: int):
    # Vocab-parallel: grid over vocab tiles, loop row tiles, accumulate dW.
    # dW[:, j] = sum_rows h_r * g_r * (onehot - p)_rj.
    d = h_ref.shape[1]
    block_v = dw_ref.shape[1]
    iv = pl.program_id(0)
    n_total = h_ref.shape[0]
    n_n = n_total // block_n

    def body(jn, acc):
        h = h_ref[pl.dslice(jn * block_n, block_n), :]  # [Bn, D]
        targets = t_ref[pl.dslice(jn * block_n, block_n)]
        lse = lse_ref[pl.dslice(jn * block_n, block_n)]
        g = g_ref[pl.dslice(jn * block_n, block_n)]
        w_tile = w_ref[:, :]  # [D, Bv] (this grid step's tile)
        x = h @ w_tile
        p = jnp.exp(x - lse[:, None])
        v_idx = iv * block_v + jax.lax.broadcasted_iota(jnp.int32, (block_n, block_v), 1)
        hit = (v_idx == targets[:, None]).astype(jnp.float32)
        coeff = g[:, None] * (hit - p)
        return acc + h.T @ coeff  # [D, Bv]

    acc0 = jnp.zeros((d, block_v), dtype=jnp.float32)
    dw_ref[:, :] = jax.lax.fori_loop(0, n_n, body, acc0)


def _fused_ce_fwd_impl(h, w, targets, *, block_n: int, block_v: int):
    n, d = h.shape
    v = w.shape[1]
    block_n = min(block_n, n)
    block_v = min(block_v, v)
    if n % block_n != 0 or v % block_v != 0:
        raise ValueError(f"shapes N={n}, V={v} must divide blocks ({block_n}, {block_v})")
    grid = (n // block_n,)
    kernel = functools.partial(_fwd_kernel, block_v=block_v)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((d, v), lambda i: (0, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=True,
    )(h, w, targets)
    return tuple(out)


def fused_ce_grads(h, w, targets, lse, g_lp, *, block_n: int = DEFAULT_BLOCK_N, block_v: int = DEFAULT_BLOCK_V):
    """Pallas backward: grads of sum(g_lp * lp) wrt h and w."""
    n, d = h.shape
    v = w.shape[1]
    block_n = min(block_n, n)
    block_v = min(block_v, v)
    dh = pl.pallas_call(
        functools.partial(_dh_kernel, block_v=block_v),
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((d, v), lambda i: (0, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_n, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        interpret=True,
    )(h, w, targets, lse, g_lp)
    dw = pl.pallas_call(
        functools.partial(_dw_kernel, block_n=block_n),
        grid=(v // block_v,),
        in_specs=[
            pl.BlockSpec((n, d), lambda i: (0, 0)),
            pl.BlockSpec((d, block_v), lambda i: (0, i)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((d, block_v), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((d, v), jnp.float32),
        interpret=True,
    )(h, w, targets, lse, g_lp)
    return dh, dw


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def fused_ce(h, w, targets, block_n: int = DEFAULT_BLOCK_N, block_v: int = DEFAULT_BLOCK_V):
    """Fused unembed + log-softmax. h: [N, D], w: [D, V], targets: [N].

    Returns (target_logprob [N], logsumexp [N], entropy [N]).  Gradients flow
    only through target_logprob (metric outputs are for logging; L2
    stop-grads them).
    """
    return _fused_ce_fwd_impl(h, w, targets, block_n=block_n, block_v=block_v)


def _ce_fwd(h, w, targets, block_n, block_v):
    lp, lse, ent = _fused_ce_fwd_impl(h, w, targets, block_n=block_n, block_v=block_v)
    return (lp, lse, ent), (h, w, targets, lse)


def _ce_bwd(block_n, block_v, res, cotangents):
    h, w, targets, lse = res
    g_lp, _g_lse, _g_ent = cotangents  # metric cotangents ignored by design
    dh, dw = fused_ce_grads(h, w, targets, lse, g_lp, block_n=block_n, block_v=block_v)
    return dh, dw, None


fused_ce.defvjp(_ce_fwd, _ce_bwd)
