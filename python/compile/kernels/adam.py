"""Pallas fused Adam update (elementwise VPU kernel).

One kernel application per parameter leaf; because everything is lowered
into a single train-step HLO, XLA sees these as fused elementwise regions.
Hyper-parameters arrive as a small runtime vector so the Rust coordinator
can change the learning rate (e.g. lr=0 "dummy learning" for Tables 1-2)
without recompiling artifacts.

hyper layout: [lr, beta1, beta2, eps, bc1, bc2] where bc{1,2} are the
bias-correction terms 1 - beta**t computed in L2 from the step counter.

Perf note (EXPERIMENTS.md §Perf): BLOCK was originally 256; under
interpret=True each grid step lowers to a sequential HLO loop iteration,
so small blocks made the Adam stage dominate the fused train step
(3.0 s/step on the `small` preset).  BLOCK=65536 keeps leaves in one or a
few grid steps (still far below VMEM for f32 x 5 buffers = 1.3 MiB) and
removed the bottleneck — see the before/after table.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 65536


def _adam_kernel(hyper_ref, p_ref, g_ref, m_ref, v_ref, p_out, m_out, v_out):
    lr = hyper_ref[0]
    b1 = hyper_ref[1]
    b2 = hyper_ref[2]
    eps = hyper_ref[3]
    bc1 = hyper_ref[4]
    bc2 = hyper_ref[5]
    g = g_ref[:]
    m_new = b1 * m_ref[:] + (1.0 - b1) * g
    v_new = b2 * v_ref[:] + (1.0 - b2) * g * g
    m_hat = m_new / bc1
    v_hat = v_new / bc2
    p_out[:] = p_ref[:] - lr * m_hat / (jnp.sqrt(v_hat) + eps)
    m_out[:] = m_new
    v_out[:] = v_new


def adam_update_flat(p: jax.Array, g: jax.Array, m: jax.Array, v: jax.Array, hyper: jax.Array):
    """Adam on a flat [n] leaf (padded to BLOCK internally). hyper: [6]."""
    n = p.size
    shape = p.shape
    p1, g1, m1, v1 = (x.reshape(-1) for x in (p, g, m, v))
    pad = (-n) % BLOCK
    if pad:
        p1, g1, m1, v1 = (jnp.pad(x, (0, pad)) for x in (p1, g1, m1, v1))
    n_padded = n + pad
    grid = (n_padded // BLOCK,)
    vec_spec = pl.BlockSpec((BLOCK,), lambda i: (i,))
    hyper_spec = pl.BlockSpec((6,), lambda i: (0,))
    p_new, m_new, v_new = pl.pallas_call(
        _adam_kernel,
        grid=grid,
        in_specs=[hyper_spec, vec_spec, vec_spec, vec_spec, vec_spec],
        out_specs=[vec_spec, vec_spec, vec_spec],
        out_shape=[jax.ShapeDtypeStruct((n_padded,), jnp.float32)] * 3,
        interpret=True,
    )(hyper, p1, g1, m1, v1)
    return (
        p_new[:n].reshape(shape),
        m_new[:n].reshape(shape),
        v_new[:n].reshape(shape),
    )


def adam_update_tree(params, grads, m, v, hyper):
    """Apply the fused Adam kernel leaf-wise over a params pytree."""
    leaves_p, treedef = jax.tree_util.tree_flatten(params)
    leaves_g = treedef.flatten_up_to(grads)
    leaves_m = treedef.flatten_up_to(m)
    leaves_v = treedef.flatten_up_to(v)
    new_p, new_m, new_v = [], [], []
    for lp, lg, lm, lv in zip(leaves_p, leaves_g, leaves_m, leaves_v):
        np_, nm_, nv_ = adam_update_flat(lp, lg, lm, lv, hyper)
        new_p.append(np_)
        new_m.append(nm_)
        new_v.append(nv_)
    return (
        jax.tree_util.tree_unflatten(treedef, new_p),
        jax.tree_util.tree_unflatten(treedef, new_m),
        jax.tree_util.tree_unflatten(treedef, new_v),
    )
