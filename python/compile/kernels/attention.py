"""Pallas tiled causal flash-attention (L1 hot-spot of the training path).

TPU adaptation of the GPU flash-attention insight (DESIGN.md
§Hardware-Adaptation): the [T, T] score matrix never touches HBM. The grid
iterates over (batch, head, q-tile); each step streams K/V tiles through
VMEM while an online-softmax accumulator (running max, running denominator,
weighted-value accumulator) is carried in registers. On real TPU the K/V
BlockSpec would double-buffer HBM->VMEM DMA; under interpret=True (the only
mode the CPU PJRT plugin can execute) the same schedule runs as numpy.

VMEM budget per grid step (f32): q-tile Bq*dh + K/V 2*T*dh + acc Bq*dh +
scores Bq*Bk.  For the `base` preset (T=256, dh=64, Bq=Bk=64) that is
~180 KiB — far below the ~16 MiB/core VMEM, leaving room for the
double-buffered pipeline.

Backward: custom_vjp with a rematerializing jnp backward (standard
flash-attention practice: recompute scores tile-by-tile; here the remat is
a single jnp pass since interpret mode has no memory cliff).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NEG_INF

DEFAULT_BLOCK_Q = 32
DEFAULT_BLOCK_K = 32


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, causal: bool, scale: float):
    # q_ref: [1, 1, Bq, dh]; k_ref/v_ref: [1, 1, T, dh]; o_ref: [1, 1, Bq, dh]
    block_q = q_ref.shape[2]
    dh = q_ref.shape[3]
    t = k_ref.shape[2]
    n_k = t // block_k
    iq = pl.program_id(2)

    q = q_ref[0, 0, :, :] * scale  # [Bq, dh]
    q_idx = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)

    def body(jk, carry):
        m_prev, l_prev, acc_prev = carry
        k_tile = k_ref[0, 0, pl.dslice(jk * block_k, block_k), :]  # [Bk, dh]
        v_tile = v_ref[0, 0, pl.dslice(jk * block_k, block_k), :]
        s = q @ k_tile.T  # [Bq, Bk]
        if causal:
            k_idx = jk * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
            s = jnp.where(q_idx >= k_idx, s, NEG_INF)
        m_cur = jnp.max(s, axis=-1)  # [Bq]
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)  # rescale of old accumulator
        p = jnp.exp(s - m_new[:, None])  # [Bq, Bk]
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc_new = acc_prev * alpha[:, None] + p @ v_tile
        return m_new, l_new, acc_new

    m0 = jnp.full((block_q,), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((block_q,), dtype=jnp.float32)
    acc0 = jnp.zeros((block_q, dh), dtype=jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_k, body, (m0, l0, acc0))
    o_ref[0, 0, :, :] = (acc / l[:, None]).astype(o_ref.dtype)


def _flash_attention_fwd_impl(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    block_q: int,
    block_k: int,
) -> jax.Array:
    b, h, t, dh = q.shape
    if t % block_q != 0 or t % block_k != 0:
        raise ValueError(f"seq len {t} must divide block sizes ({block_q}, {block_k})")
    scale = 1.0 / float(dh) ** 0.5
    grid = (b, h, t // block_q)
    kernel = functools.partial(_attn_kernel, block_k=block_k, causal=causal, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, dh), lambda ib, ih, iq: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, t, dh), lambda ib, ih, iq: (ib, ih, 0, 0)),
            pl.BlockSpec((1, 1, t, dh), lambda ib, ih, iq: (ib, ih, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, dh), lambda ib, ih, iq: (ib, ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=True,
    )(q, k, v)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
) -> jax.Array:
    """Tiled causal attention. q, k, v: [B, H, T, dh] -> [B, H, T, dh]."""
    return _flash_attention_fwd_impl(q, k, v, causal=causal, block_q=block_q, block_k=block_k)


def _fwd(q, k, v, causal, block_q, block_k):
    o = _flash_attention_fwd_impl(q, k, v, causal=causal, block_q=block_q, block_k=block_k)
    return o, (q, k, v)


def _bwd(causal, block_q, block_k, res, do):
    q, k, v = res
    dh = q.shape[-1]
    scale = 1.0 / float(dh) ** 0.5
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    if causal:
        t = q.shape[2]
        mask = jnp.tril(jnp.ones((t, t), dtype=bool))
        s = jnp.where(mask[None, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    dv = jnp.einsum("bhqk,bhqd->bhkd", p, do)
    dp = jnp.einsum("bhqd,bhkd->bhqk", do, v)
    ds = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
    dq = jnp.einsum("bhqk,bhkd->bhqd", ds, k) * scale
    dk = jnp.einsum("bhqk,bhqd->bhkd", ds, q) * scale
    return dq, dk, dv


flash_attention.defvjp(_fwd, _bwd)
