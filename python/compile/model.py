"""L2: the policy LLM as pure-functional JAX, calling the L1 Pallas kernels.

A GPT-style decoder-only transformer (pre-RMSNorm, RoPE, SiLU MLP) sized by
preset.  Entry points (all lowered to HLO by aot.py):

  * ``forward_hidden``  — final hidden states (flash-attention kernel inside)
  * ``token_logprobs``  — per-token log-probs + entropy via the fused-CE kernel
  * ``prefill``         — prompt forward + KV-cache population + last logits
  * ``decode_step``     — single-token decode against the KV cache
  * ``pooled_embed``    — mean-pooled, L2-normalized sequence embedding
                          (the GTE-embedder stand-in for diversity rewards)

Parameter pytree is a flat dict keyed by zero-padded names so that JAX's
sorted-dict flattening order is deterministic; the AOT manifest records the
order and Rust's ParamStore reproduces it exactly.
"""

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .kernels.attention import flash_attention
from .kernels.fused_ce import fused_ce

Params = Dict[str, jax.Array]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab_size: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    max_seq: int

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def param_count(self) -> int:
        per_layer = 4 * self.d_model**2 + 2 * self.d_model * self.d_ff + 2 * self.d_model
        return (
            2 * self.vocab_size * self.d_model
            + self.n_layers * per_layer
            + self.d_model
        )


PRESETS = {
    # vocab sizes are multiples of the fused-CE vocab tile (128)
    "tiny": ModelConfig("tiny", 512, 64, 2, 4, 256, 64),
    "small": ModelConfig("small", 1024, 192, 4, 6, 768, 128),
    "base": ModelConfig("base", 4096, 512, 8, 8, 2048, 256),
    "large": ModelConfig("large", 16384, 768, 12, 12, 3072, 512),
}


# ---------------------------------------------------------------------------
# parameters


def param_spec(cfg: ModelConfig):
    """(name -> (shape, init_std)) — init_std 0.0 means 'init to ones' (norms)."""
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab_size
    std = 0.02
    out_std = std / (2 * cfg.n_layers) ** 0.5  # residual-branch scaling
    spec = {
        "tok_emb": ((v, d), std),
        "unembed": ((d, v), std),
        "final_norm": ((d,), 0.0),
    }
    for i in range(cfg.n_layers):
        p = f"layers.{i:02d}."
        spec[p + "attn_norm"] = ((d,), 0.0)
        spec[p + "wq"] = ((d, d), std)
        spec[p + "wk"] = ((d, d), std)
        spec[p + "wv"] = ((d, d), std)
        spec[p + "wo"] = ((d, d), out_std)
        spec[p + "mlp_norm"] = ((d,), 0.0)
        spec[p + "w_up"] = ((d, f), std)
        spec[p + "w_down"] = ((f, d), out_std)
    return spec


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    params = {}
    for i, (name, (shape, std)) in enumerate(sorted(param_spec(cfg).items())):
        if std == 0.0:
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            sub = jax.random.fold_in(key, i)
            params[name] = std * jax.random.normal(sub, shape, jnp.float32)
    return params


def param_shapes(cfg: ModelConfig):
    """Flattened leaf order as jax will see it (sorted dict keys)."""
    spec = param_spec(cfg)
    return [(name, spec[name][0], spec[name][1]) for name in sorted(spec)]


# ---------------------------------------------------------------------------
# building blocks


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * scale


def rope_angles(positions: jax.Array, head_dim: int, theta: float = 10000.0):
    """positions: int32 [...]. Returns (cos, sin) with shape [..., head_dim/2]."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: [..., head_dim]; cos/sin broadcastable to [..., head_dim/2]."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _attention_full(cfg: ModelConfig, params: Params, prefix: str, x: jax.Array, positions: jax.Array):
    """Full-sequence attention through the flash kernel.

    x: [B, T, D]. Returns (out [B, T, D], k_rot [B, T, H, dh], v [B, T, H, dh]).
    """
    b, t, d = x.shape
    h, dh = cfg.n_heads, cfg.head_dim
    q = (x @ params[prefix + "wq"]).reshape(b, t, h, dh)
    k = (x @ params[prefix + "wk"]).reshape(b, t, h, dh)
    v = (x @ params[prefix + "wv"]).reshape(b, t, h, dh)
    cos, sin = rope_angles(positions, dh)  # [T, dh/2]
    cos, sin = cos[None, :, None, :], sin[None, :, None, :]
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    # flash kernel wants [B, H, T, dh]
    o = flash_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)
    )
    o = o.transpose(0, 2, 1, 3).reshape(b, t, d)
    return o @ params[prefix + "wo"], k, v


def _mlp(params: Params, prefix: str, x: jax.Array) -> jax.Array:
    return jax.nn.silu(x @ params[prefix + "w_up"]) @ params[prefix + "w_down"]


# ---------------------------------------------------------------------------
# entry points


def forward_hidden(cfg: ModelConfig, params: Params, tokens: jax.Array, collect_kv: bool = False):
    """tokens: [B, T] int32 -> final hidden [B, T, D] (+ per-layer post-RoPE K/V)."""
    b, t = tokens.shape
    positions = jnp.arange(t, dtype=jnp.int32)
    x = params["tok_emb"][tokens]
    kvs = []
    for i in range(cfg.n_layers):
        p = f"layers.{i:02d}."
        attn_out, k, v = _attention_full(cfg, params, p, rms_norm(x, params[p + "attn_norm"]), positions)
        x = x + attn_out
        x = x + _mlp(params, p, rms_norm(x, params[p + "mlp_norm"]))
        if collect_kv:
            kvs.append((k, v))
    h = rms_norm(x, params["final_norm"])
    return (h, kvs) if collect_kv else h


def token_logprobs(cfg: ModelConfig, params: Params, tokens: jax.Array):
    """Per-token log-probabilities via the fused-CE kernel.

    Returns (lp [B, T], ent [B, T]) where lp[:, j] = log pi(tokens[:, j] |
    tokens[:, :j]) for j >= 1 and lp[:, 0] = 0; ent[:, j] is the entropy of
    the distribution that produced token j (stop-gradient, metric only).
    """
    b, t = tokens.shape
    h = forward_hidden(cfg, params, tokens)  # [B, T, D]
    # position j predicts token j+1; last position's target is a dummy 0.
    targets = jnp.concatenate([tokens[:, 1:], jnp.zeros((b, 1), jnp.int32)], axis=1)
    lp_full, _lse, ent_full = fused_ce(
        h.reshape(b * t, cfg.d_model), params["unembed"], targets.reshape(b * t)
    )
    lp_full = lp_full.reshape(b, t)
    ent_full = ent_full.reshape(b, t)
    zeros = jnp.zeros((b, 1), jnp.float32)
    lp = jnp.concatenate([zeros, lp_full[:, :-1]], axis=1)
    ent = jnp.concatenate([zeros, ent_full[:, :-1]], axis=1)
    return lp, jax.lax.stop_gradient(ent)


def prefill(cfg: ModelConfig, params: Params, tokens: jax.Array, prompt_lens: jax.Array, cache_len: int):
    """Prompt forward populating a KV cache.

    tokens: [B, Tp] right-padded prompts; prompt_lens: [B] int32.
    Returns (last_logits [B, V], k_cache, v_cache [L, B, Tc, H, dh]).
    Pad positions write garbage K/V beyond prompt_lens; decode overwrites
    position `pos` before attending to it, so they are never observed.
    """
    b, tp = tokens.shape
    h, kvs = forward_hidden(cfg, params, tokens, collect_kv=True)
    k_cache = jnp.zeros((cfg.n_layers, b, cache_len, cfg.n_heads, cfg.head_dim), jnp.float32)
    v_cache = jnp.zeros_like(k_cache)
    for i, (k, v) in enumerate(kvs):
        k_cache = k_cache.at[i, :, :tp].set(k)
        v_cache = v_cache.at[i, :, :tp].set(v)
    last_h = jnp.take_along_axis(h, (prompt_lens - 1)[:, None, None], axis=1)[:, 0]  # [B, D]
    last_logits = last_h @ params["unembed"]
    return last_logits, k_cache, v_cache


def decode_step(
    cfg: ModelConfig,
    params: Params,
    k_cache: jax.Array,
    v_cache: jax.Array,
    tokens: jax.Array,
    pos: jax.Array,
):
    """One decode step with per-sequence positions (continuous batching).

    tokens: [B] int32 (the token at position pos[b]); pos: [B] int32.
    Returns (logits [B, V], k_cache', v_cache').
    """
    b = tokens.shape[0]
    hcount, dh = cfg.n_heads, cfg.head_dim
    tc = k_cache.shape[2]
    x = params["tok_emb"][tokens]  # [B, D]
    cos, sin = rope_angles(pos, dh)  # [B, dh/2]
    cos_h, sin_h = cos[:, None, :], sin[:, None, :]
    t_idx = jnp.arange(tc, dtype=jnp.int32)
    scale = 1.0 / float(dh) ** 0.5

    def write(cache_l, new, p):
        # cache_l: [B, Tc, H, dh], new: [B, H, dh]
        return jax.vmap(
            lambda c, n, pp: jax.lax.dynamic_update_slice(c, n[None], (pp, 0, 0))
        )(cache_l, new, p)

    for i in range(cfg.n_layers):
        pfx = f"layers.{i:02d}."
        hn = rms_norm(x, params[pfx + "attn_norm"])
        q = (hn @ params[pfx + "wq"]).reshape(b, hcount, dh)
        k = (hn @ params[pfx + "wk"]).reshape(b, hcount, dh)
        v = (hn @ params[pfx + "wv"]).reshape(b, hcount, dh)
        q = apply_rope(q, cos_h, sin_h)
        k = apply_rope(k, cos_h, sin_h)
        k_cache = k_cache.at[i].set(write(k_cache[i], k, pos))
        v_cache = v_cache.at[i].set(write(v_cache[i], v, pos))
        scores = jnp.einsum("bhd,bthd->bht", q, k_cache[i]) * scale
        mask = t_idx[None, :] <= pos[:, None]  # attend to 0..pos inclusive
        scores = jnp.where(mask[:, None, :], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        o = jnp.einsum("bht,bthd->bhd", probs, v_cache[i]).reshape(b, cfg.d_model)
        x = x + o @ params[pfx + "wo"]
        x = x + _mlp(params, pfx, rms_norm(x, params[pfx + "mlp_norm"]))
    hfin = rms_norm(x, params["final_norm"])
    return hfin @ params["unembed"], k_cache, v_cache


def pooled_embed(cfg: ModelConfig, params: Params, tokens: jax.Array, mask: jax.Array):
    """Mean-pooled, L2-normalized final hidden state. mask: [B, T] f32."""
    h = forward_hidden(cfg, params, tokens)  # [B, T, D]
    s = jnp.sum(h * mask[:, :, None], axis=1)
    denom = jnp.maximum(jnp.sum(mask, axis=1, keepdims=True), 1.0)
    emb = s / denom
    norm = jnp.maximum(jnp.linalg.norm(emb, axis=-1, keepdims=True), 1e-8)
    return emb / norm
