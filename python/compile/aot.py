"""AOT compile path: lower every L2 entry point to HLO text + manifest.

Python runs exactly once (``make artifacts``); the Rust coordinator then
loads ``artifacts/*.hlo.txt`` through the PJRT C API and never touches
Python again.

Interchange format is HLO **text**: jax >= 0.5 serializes HloModuleProto
with 64-bit instruction ids which xla_extension 0.5.1 (the version the
published `xla` crate binds) rejects; the text parser reassigns ids and
round-trips cleanly.  See /opt/xla-example/README.md.

The manifest (artifacts/manifest.json) is the contract with Rust: model
configs, parameter leaf order (jax sorted-dict flattening), per-artifact
input/output descriptors with roles, hyper-vector slot names, and metric
slot names.
"""

import argparse
import functools
import json
import os
from typing import Any, Dict, List

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import losses, model
from .model import PRESETS, ModelConfig

HYPER_SLOTS = ["lr", "beta1", "beta2", "adam_eps", "clip_eps", "tau_or_beta", "mu", "kl_coef"]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _params_spec(cfg: ModelConfig):
    return {name: _spec(shape) for name, shape, _ in model.param_shapes(cfg)}


def _leaf_descriptors(tree, role_fn) -> List[Dict[str, Any]]:
    """Flatten a pytree of ShapeDtypeStructs into named descriptors."""
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for i, (path, leaf) in enumerate(leaves):
        name = jax.tree_util.keystr(path, simple=True, separator="/") if path else f"leaf{i}"
        out.append(
            {
                "name": name,
                "shape": list(leaf.shape),
                "dtype": str(leaf.dtype),
                "role": role_fn(path),
            }
        )
    return out


def _role_for_top(top_names: List[str]):
    def role_fn(path):
        if not path:
            return top_names[0]
        idx = path[0].idx if hasattr(path[0], "idx") else 0
        return top_names[idx]

    return role_fn


class ArtifactBuilder:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.manifest: Dict[str, Any] = {
            "version": 1,
            "hyper_slots": HYPER_SLOTS,
            "models": {},
            "artifacts": {},
        }

    def add_model(self, cfg: ModelConfig):
        self.manifest["models"][cfg.name] = {
            "vocab_size": cfg.vocab_size,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff,
            "max_seq": cfg.max_seq,
            "param_count": cfg.param_count(),
            "params": [
                {"name": n, "shape": list(s), "init_std": std}
                for n, s, std in model.param_shapes(cfg)
            ],
        }

    def lower(self, name: str, fn, example_args, in_roles: List[str], out_roles: List[str], extra: Dict[str, Any]):
        """Lower fn(*example_args), write HLO text, record manifest entry."""
        print(f"[aot] lowering {name} ...", flush=True)
        # keep_unused: the manifest promises every input is an HLO parameter,
        # even leaves a particular entry point doesn't read (e.g. `unembed`
        # in the embed artifact) — Rust feeds the full param set uniformly.
        lowered = jax.jit(fn, keep_unused=True).lower(*example_args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        out_shape = jax.eval_shape(fn, *example_args)
        inputs = _leaf_descriptors(tuple(example_args), _role_for_top(in_roles))
        outputs = _leaf_descriptors(out_shape, _role_for_top(out_roles))
        entry = {"file": fname, "inputs": inputs, "outputs": outputs}
        entry.update(extra)
        self.manifest["artifacts"][name] = entry
        print(f"[aot]   wrote {fname} ({len(text)} chars, {len(inputs)} in, {len(outputs)} out)", flush=True)

    def save_manifest(self):
        path = os.path.join(self.out_dir, "manifest.json")
        with open(path, "w") as f:
            json.dump(self.manifest, f, indent=1)
        print(f"[aot] wrote {path}")


# ---------------------------------------------------------------------------
# artifact set definitions


def build_generation(b: ArtifactBuilder, cfg: ModelConfig, batch: int, prompt_len: int, cache_len: int):
    p = _params_spec(cfg)
    prefill_fn = functools.partial(model.prefill, cfg, cache_len=cache_len)
    b.lower(
        f"{cfg.name}_prefill_b{batch}_t{prompt_len}",
        lambda params, tokens, lens: prefill_fn(params, tokens, lens),
        (p, _spec((batch, prompt_len), jnp.int32), _spec((batch,), jnp.int32)),
        ["param", "data", "data"],
        ["data", "data", "data"],
        {
            "model": cfg.name,
            "kind": "prefill",
            "batch": batch,
            "seq": prompt_len,
            "cache_len": cache_len,
        },
    )
    b.lower(
        f"{cfg.name}_decode_b{batch}",
        functools.partial(model.decode_step, cfg),
        (
            p,
            _spec((cfg.n_layers, batch, cache_len, cfg.n_heads, cfg.head_dim)),
            _spec((cfg.n_layers, batch, cache_len, cfg.n_heads, cfg.head_dim)),
            _spec((batch,), jnp.int32),
            _spec((batch,), jnp.int32),
        ),
        ["param", "data", "data", "data", "data"],
        ["data", "data", "data"],
        {"model": cfg.name, "kind": "decode", "batch": batch, "cache_len": cache_len},
    )


def build_logprobs(b: ArtifactBuilder, cfg: ModelConfig, batch: int, seq: int):
    b.lower(
        f"{cfg.name}_logprobs_b{batch}_t{seq}",
        functools.partial(model.token_logprobs, cfg),
        (_params_spec(cfg), _spec((batch, seq), jnp.int32)),
        ["param", "data"],
        ["data", "data"],
        {"model": cfg.name, "kind": "logprobs", "batch": batch, "seq": seq},
    )


def build_embed(b: ArtifactBuilder, cfg: ModelConfig, batch: int, seq: int):
    b.lower(
        f"{cfg.name}_embed_b{batch}_t{seq}",
        functools.partial(model.pooled_embed, cfg),
        (_params_spec(cfg), _spec((batch, seq), jnp.int32), _spec((batch, seq))),
        ["param", "data", "data"],
        ["data"],
        {"model": cfg.name, "kind": "embed", "batch": batch, "seq": seq},
    )


def _train_data_spec(alg: str, batch: int, seq: int):
    tok = _spec((batch, seq), jnp.int32)
    f_bt = _spec((batch, seq))
    f_b = _spec((batch,))
    if alg in ("grpo", "ppo"):
        return (tok, f_bt, f_b, f_bt), ["tokens", "mask", "advantages", "old_lp"]
    if alg == "sft":
        return (tok, f_bt), ["tokens", "mask"]
    if alg == "dpo":
        return (tok, f_bt, tok, f_bt, f_b, f_b), [
            "tokens_chosen",
            "mask_chosen",
            "tokens_rejected",
            "mask_rejected",
            "ref_lp_chosen",
            "ref_lp_rejected",
        ]
    if alg == "mix":
        return (tok, f_bt, f_b, f_bt, f_b), ["tokens", "mask", "advantages", "old_lp", "is_expert"]
    if alg.startswith("opmd"):
        return (tok, f_bt, f_b, f_bt), ["tokens", "mask", "rewards", "old_lp"]
    raise ValueError(alg)


def build_train(b: ArtifactBuilder, cfg: ModelConfig, alg: str, batch: int, seq: int, group_size: int = 1):
    p = _params_spec(cfg)
    data, data_names = _train_data_spec(alg, batch, seq)
    step_fn = losses.make_train_step(cfg, alg, group_size=group_size)
    example = (p, p, p, _spec((), jnp.float32), _spec((len(HYPER_SLOTS),))) + data
    in_roles = ["param", "opt_m", "opt_v", "step", "hyper"] + ["data"] * len(data)
    name = f"{cfg.name}_train_{alg}_b{batch}_t{seq}"
    b.lower(
        name,
        step_fn,
        example,
        in_roles,
        ["param", "opt_m", "opt_v", "metrics"],
        {
            "model": cfg.name,
            "kind": "train",
            "alg": alg,
            "batch": batch,
            "seq": seq,
            "group_size": group_size,
            "data_inputs": data_names,
            "metrics": losses.metric_names(alg),
        },
    )


DEFAULT_SETS = {
    # preset -> dict describing the artifact bundle
    "tiny": {
        "gen": [(4, 32, 64)],  # (batch, prompt_len, cache_len)
        "logprobs": [(4, 64)],
        "embed": [(4, 64)],
        "train": [
            ("grpo", 4, 64, 4),
            ("ppo", 4, 64, 4),
            ("sft", 4, 64, 1),
            ("dpo", 2, 64, 1),
            ("mix", 4, 64, 4),
            ("opmd_kimi", 4, 64, 4),
            ("opmd_pairwise", 4, 64, 4),
            ("opmd_simple", 4, 64, 4),
        ],
    },
    "small": {
        "gen": [(8, 64, 128)],
        "logprobs": [(8, 128)],
        "embed": [(8, 128)],
        "train": [
            ("grpo", 8, 128, 8),
            ("sft", 8, 128, 1),
            ("mix", 8, 128, 8),
            ("opmd_simple", 8, 128, 8),
        ],
    },
    "base": {
        "gen": [(8, 64, 256)],
        "logprobs": [(8, 256)],
        "embed": [(8, 256)],
        "train": [("grpo", 8, 256, 8), ("sft", 8, 256, 1)],
    },
    "large": {
        "gen": [(4, 128, 512)],
        "logprobs": [(4, 512)],
        "embed": [(4, 512)],
        "train": [("grpo", 4, 512, 4)],
    },
}


def build_preset(b: ArtifactBuilder, preset: str):
    cfg = PRESETS[preset]
    spec = DEFAULT_SETS[preset]
    b.add_model(cfg)
    for batch, prompt_len, cache_len in spec["gen"]:
        build_generation(b, cfg, batch, prompt_len, cache_len)
    for batch, seq in spec["logprobs"]:
        build_logprobs(b, cfg, batch, seq)
    for batch, seq in spec["embed"]:
        build_embed(b, cfg, batch, seq)
    for alg, batch, seq, group in spec["train"]:
        build_train(b, cfg, alg, batch, seq, group)


def main():
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument(
        "--presets",
        default="tiny,small",
        help="comma-separated model presets to build (tiny,small,base,large)",
    )
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    b = ArtifactBuilder(args.out_dir)
    for preset in args.presets.split(","):
        build_preset(b, preset.strip())
    b.save_manifest()


if __name__ == "__main__":
    main()
