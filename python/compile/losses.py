"""L2: RL loss functions + fused train steps (loss -> grads -> Adam in one HLO).

Implements the paper's algorithm registry (§3.2, Appendix A):

  * ``grpo``          — clipped policy gradient with group advantages
                        (advantages are computed in Rust from grouped rewards;
                        ratio clipping handles off-policyness as in the paper)
  * ``ppo``           — same surrogate with an active KL penalty slot
  * ``sft``           — supervised fine-tuning on masked response tokens
  * ``dpo``           — direct preference optimization on chosen/rejected pairs
  * ``mix``           — (1-mu) * GRPO(usual) + mu * SFT(expert)   (paper §3.2)
  * ``opmd_kimi``     — Kimi k1.5 OPMD surrogate (Appendix A.1)
  * ``opmd_pairwise`` — pairwise OPMD (Appendix A.2)
  * ``opmd_simple``   — the "embarrassingly simple" variant (Appendix A.3),
                        i.e. baseline-subtracted PG scaled by 1/(1+tau)

The hyper-parameter vector is a runtime input so the Rust coordinator can
set lr=0 for dummy-learning profiling (Tables 1-2) without recompiling:

  hyper = [lr, beta1, beta2, adam_eps, clip_eps, tau_or_beta, mu, kl_coef]

Every train step returns a fixed-width metrics vector; slot names are
recorded per-algorithm in the AOT manifest.
"""

from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels.adam import adam_update_tree
from .model import ModelConfig, Params, token_logprobs

N_METRICS = 8

H_LR, H_B1, H_B2, H_EPS, H_CLIP, H_TAU, H_MU, H_KL = range(8)


def masked_mean(x: jax.Array, mask: jax.Array) -> jax.Array:
    return jnp.sum(x * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def _seq_logprob(lp: jax.Array, mask: jax.Array) -> jax.Array:
    """Sequence log-prob: sum of masked token log-probs. [B, T] -> [B]."""
    return jnp.sum(lp * mask, axis=1)


# ---------------------------------------------------------------------------
# loss functions: fn(cfg, params, hyper, *data) -> (loss, metrics[N_METRICS])


def _pg_clip_core(lp, ent, mask, advantages, old_lp, clip_eps, kl_coef, weight=None):
    """Shared clipped-PG surrogate. weight: optional [B] per-sequence weight."""
    log_ratio = lp - old_lp
    ratio = jnp.exp(log_ratio)
    adv = advantages[:, None]
    w_mask = mask if weight is None else mask * weight[:, None]
    unclipped = ratio * adv
    clipped = jnp.clip(ratio, 1.0 - clip_eps, 1.0 + clip_eps) * adv
    pg_loss = -masked_mean(jnp.minimum(unclipped, clipped), w_mask)
    # k3 estimator of KL(new || old) is standard; the paper logs KL magnitude.
    kl = masked_mean(jnp.exp(-log_ratio) - 1.0 + log_ratio, w_mask)
    clip_frac = masked_mean((jnp.abs(ratio - 1.0) > clip_eps).astype(jnp.float32), w_mask)
    entropy = masked_mean(ent, w_mask)
    loss = pg_loss + kl_coef * kl
    return loss, pg_loss, kl, clip_frac, entropy, masked_mean(ratio, w_mask)


def grpo_loss(cfg: ModelConfig, params: Params, hyper, tokens, mask, advantages, old_lp):
    lp, ent = token_logprobs(cfg, params, tokens)
    loss, pg, kl, clip_frac, entropy, ratio = _pg_clip_core(
        lp, ent, mask, advantages, old_lp, hyper[H_CLIP], hyper[H_KL]
    )
    metrics = jnp.stack([loss, pg, kl, clip_frac, entropy, ratio, jnp.mean(advantages), 0.0])
    return loss, metrics


GRPO_METRICS = ["loss", "pg_loss", "kl", "clip_frac", "entropy", "ratio", "adv_mean", "_"]


def sft_loss(cfg: ModelConfig, params: Params, hyper, tokens, mask):
    lp, ent = token_logprobs(cfg, params, tokens)
    loss = -masked_mean(lp, mask)
    metrics = jnp.stack([loss, loss, 0.0, 0.0, masked_mean(ent, mask), 0.0, 0.0, 0.0])
    return loss, metrics


SFT_METRICS = ["loss", "nll", "_", "_", "entropy", "_", "_", "_"]


def dpo_loss(cfg: ModelConfig, params: Params, hyper, tokens_c, mask_c, tokens_r, mask_r, ref_c, ref_r):
    beta = hyper[H_TAU]
    lp_c, _ = token_logprobs(cfg, params, tokens_c)
    lp_r, _ = token_logprobs(cfg, params, tokens_r)
    seq_c = _seq_logprob(lp_c, mask_c)
    seq_r = _seq_logprob(lp_r, mask_r)
    margin = beta * ((seq_c - ref_c) - (seq_r - ref_r))
    loss = -jnp.mean(jax.nn.log_sigmoid(margin))
    acc = jnp.mean((margin > 0).astype(jnp.float32))
    metrics = jnp.stack(
        [loss, jnp.mean(margin), acc, jnp.mean(seq_c - ref_c), jnp.mean(seq_r - ref_r), 0.0, 0.0, 0.0]
    )
    return loss, metrics


DPO_METRICS = ["loss", "margin", "accuracy", "chosen_delta", "rejected_delta", "_", "_", "_"]


def mix_loss(cfg: ModelConfig, params: Params, hyper, tokens, mask, advantages, old_lp, is_expert):
    """Paper §3.2 MIX: (1-mu) * GRPO on usual rollouts + mu * SFT on expert."""
    mu = hyper[H_MU]
    lp, ent = token_logprobs(cfg, params, tokens)
    usual = 1.0 - is_expert
    grpo_part, pg, kl, clip_frac, entropy, _ = _pg_clip_core(
        lp, ent, mask, advantages, old_lp, hyper[H_CLIP], hyper[H_KL], weight=usual
    )
    sft_part = -masked_mean(lp, mask * is_expert[:, None])
    loss = (1.0 - mu) * grpo_part + mu * sft_part
    metrics = jnp.stack([loss, grpo_part, sft_part, kl, clip_frac, entropy, jnp.mean(is_expert), 0.0])
    return loss, metrics


MIX_METRICS = ["loss", "grpo_loss", "sft_loss", "kl", "clip_frac", "entropy", "expert_frac", "_"]


def _group_reshape(x: jax.Array, group_size: int) -> jax.Array:
    return x.reshape(-1, group_size)


def opmd_kimi_loss(cfg: ModelConfig, params: Params, hyper, tokens, mask, rewards, old_lp, *, group_size: int):
    """Kimi k1.5 OPMD (Appendix A.1): squared consistency residual with
    log Z-hat estimated from the group's rewards."""
    tau = hyper[H_TAU]
    lp, ent = token_logprobs(cfg, params, tokens)
    seq_lp = _seq_logprob(lp, mask)
    ref_lp = _seq_logprob(old_lp, mask)  # rollout policy = pi_ref at sampling time
    r_g = _group_reshape(rewards, group_size)  # [G, K]
    # tau * log( (1/K) sum exp(r/tau) ) — computed stably per group.
    m = jnp.max(r_g, axis=1, keepdims=True)
    log_z = tau * jnp.log(jnp.mean(jnp.exp((r_g - m) / jnp.maximum(tau, 1e-6)), axis=1)) + m[:, 0]
    resid = r_g - log_z[:, None] - tau * _group_reshape(seq_lp - ref_lp, group_size)
    loss = jnp.mean(resid**2)
    metrics = jnp.stack(
        [loss, jnp.mean(rewards), jnp.mean(seq_lp), masked_mean(ent, mask), jnp.mean(log_z), 0.0, 0.0, 0.0]
    )
    return loss, metrics


OPMD_KIMI_METRICS = ["loss", "reward_mean", "seq_lp", "entropy", "log_z", "_", "_", "_"]


def opmd_pairwise_loss(cfg: ModelConfig, params: Params, hyper, tokens, mask, rewards, old_lp, *, group_size: int):
    """Pairwise OPMD (Appendix A.2): sum_{i<j} (a_i - a_j)^2 with
    a_i = r_i - tau (log pi - log pi_ref); Z eliminated by pairing.
    Uses the identity sum_{i<j}(a_i-a_j)^2 = K*sum a^2 - (sum a)^2."""
    tau = hyper[H_TAU]
    lp, ent = token_logprobs(cfg, params, tokens)
    seq_lp = _seq_logprob(lp, mask)
    ref_lp = _seq_logprob(old_lp, mask)
    a = _group_reshape(rewards - tau * (seq_lp - ref_lp), group_size)  # [G, K]
    k = float(group_size)
    per_group = k * jnp.sum(a**2, axis=1) - jnp.sum(a, axis=1) ** 2
    loss = jnp.mean(per_group) / (k * k)  # scale-normalize by pair count
    metrics = jnp.stack(
        [loss, jnp.mean(rewards), jnp.mean(seq_lp), masked_mean(ent, mask), jnp.mean(a), 0.0, 0.0, 0.0]
    )
    return loss, metrics


OPMD_PAIRWISE_METRICS = ["loss", "reward_mean", "seq_lp", "entropy", "a_mean", "_", "_", "_"]


def opmd_simple_loss(cfg: ModelConfig, params: Params, hyper, tokens, mask, rewards, old_lp, *, group_size: int):
    """Simple OPMD (Appendix A.3): -1/(1+tau) * sum_i (r_i - rbar_group) log pi.

    Exactly the standard policy gradient with the group-mean baseline, but
    derived via one-step mirror descent — valid off-policy per the paper."""
    tau = hyper[H_TAU]
    lp, ent = token_logprobs(cfg, params, tokens)
    seq_lp = _seq_logprob(lp, mask)
    r_g = _group_reshape(rewards, group_size)
    baseline = jnp.mean(r_g, axis=1, keepdims=True)
    adv = (r_g - baseline).reshape(-1)
    loss = -jnp.mean(adv * seq_lp) / (1.0 + tau)
    metrics = jnp.stack(
        [loss, jnp.mean(rewards), jnp.mean(seq_lp), masked_mean(ent, mask), jnp.mean(jnp.abs(adv)), 0.0, 0.0, 0.0]
    )
    return loss, metrics


OPMD_SIMPLE_METRICS = ["loss", "reward_mean", "seq_lp", "entropy", "adv_abs", "_", "_", "_"]


ALGORITHMS: Dict[str, Tuple[Callable, List[str], bool]] = {
    # name -> (loss_fn, metric names, needs_group_size)
    "grpo": (grpo_loss, GRPO_METRICS, False),
    "ppo": (grpo_loss, GRPO_METRICS, False),  # same surrogate; kl_coef active
    "sft": (sft_loss, SFT_METRICS, False),
    "dpo": (dpo_loss, DPO_METRICS, False),
    "mix": (mix_loss, MIX_METRICS, False),
    "opmd_kimi": (opmd_kimi_loss, OPMD_KIMI_METRICS, True),
    "opmd_pairwise": (opmd_pairwise_loss, OPMD_PAIRWISE_METRICS, True),
    "opmd_simple": (opmd_simple_loss, OPMD_SIMPLE_METRICS, True),
}


def global_grad_norm(grads) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(grads)
    return jnp.sqrt(sum(jnp.sum(g * g) for g in leaves))


def make_train_step(cfg: ModelConfig, alg: str, group_size: int = 1):
    """Build step(params, m, v, step_count, hyper, *data) ->
    (params', m', v', metrics[N_METRICS+1]) — last metric slot is grad_norm."""
    loss_fn, _names, needs_group = ALGORITHMS[alg]

    def step(params, m, v, step_count, hyper, *data):
        def wrapped(p):
            if needs_group:
                return loss_fn(cfg, p, hyper, *data, group_size=group_size)
            return loss_fn(cfg, p, hyper, *data)

        (_loss, metrics), grads = jax.value_and_grad(wrapped, has_aux=True)(params)
        gnorm = global_grad_norm(grads)
        t = step_count.astype(jnp.float32)
        adam_hyper = jnp.stack(
            [
                hyper[H_LR],
                hyper[H_B1],
                hyper[H_B2],
                hyper[H_EPS],
                1.0 - hyper[H_B1] ** t,
                1.0 - hyper[H_B2] ** t,
            ]
        )
        params, m, v = adam_update_tree(params, grads, m, v, adam_hyper)
        return params, m, v, jnp.concatenate([metrics, gnorm[None]])

    return step


def metric_names(alg: str) -> List[str]:
    return ALGORITHMS[alg][1] + ["grad_norm"]
